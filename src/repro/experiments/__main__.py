"""CLI for the baseline-vs-ASI experiment harness.

    python -m repro.experiments --smoke
    python -m repro.experiments --workloads circuit pennant --min-wins 2
    python -m repro.experiments --workloads circuit --ablate-feedback
    python -m repro.experiments --seeds 0 1 2 --iters 10 --out bench.json

Exit code is non-zero when --min-wins is not met or a determinism check
fails, so CI can gate on the comparison.
"""

from __future__ import annotations

import argparse
import sys

from .runner import (DEFAULT_OPTIMIZERS, SMOKE_WORKLOADS, ExperimentConfig,
                     format_table, run_experiments)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments",
        description="Sweep {optimizer x workload x feedback-level} and "
                    "compare the agentic ASI optimizer against scalar "
                    "auto-tuner baselines.")
    ap.add_argument("--smoke", action="store_true",
                    help=f"default fast sweep: {', '.join(SMOKE_WORKLOADS)}")
    ap.add_argument("--workloads", nargs="+", default=None,
                    help="registry names (default: the smoke set)")
    ap.add_argument("--optimizers", nargs="+", default=None,
                    help="subset of optimizer arms by name "
                         f"(default: {', '.join(o.name for o in DEFAULT_OPTIMIZERS)})")
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--seeds", nargs="+", type=int, default=[0])
    ap.add_argument("--ablate-feedback", action="store_true",
                    help="sweep every optimizer across all four feedback "
                         "levels (Fig. 8 axis) instead of each arm's own")
    ap.add_argument("--out", default="BENCH_experiments.json",
                    help="summary JSON path (default: "
                         "BENCH_experiments.json)")
    ap.add_argument("--publish-store", default=None, metavar="PATH",
                    help="publish each workload's sweep winner to this "
                         "mapper artifact store (see repro.service)")
    ap.add_argument("--min-wins", type=int, default=None,
                    help="exit 1 unless the ASI arm strictly beats every "
                         "scalar baseline on at least this many workloads")
    ap.add_argument("--no-determinism-check", action="store_true",
                    help="skip the same-seed rerun and LLM record/replay "
                         "verification")
    args = ap.parse_args(argv)

    optimizers = DEFAULT_OPTIMIZERS
    if args.optimizers:
        by_name = {o.name: o for o in DEFAULT_OPTIMIZERS}
        unknown = [n for n in args.optimizers if n not in by_name]
        if unknown:
            ap.error(f"unknown optimizer(s) {unknown}; choose from "
                     f"{sorted(by_name)}")
        optimizers = tuple(by_name[n] for n in args.optimizers)

    cfg = ExperimentConfig(
        workloads=tuple(args.workloads) if args.workloads
        else SMOKE_WORKLOADS,
        optimizers=optimizers,
        iterations=args.iters,
        seeds=tuple(args.seeds),
        feedback_levels=(("scalar", "system", "explain", "full")
                         if args.ablate_feedback else None),
        check_determinism=not args.no_determinism_check,
        check_llm_replay=not args.no_determinism_check,
        out=args.out,
        publish_store=args.publish_store,
    )
    # validate names up front: a KeyError out of the sweep itself is a
    # bug that deserves its traceback, not a terse config error
    from ..asi import registry
    known = registry.names()
    unknown = [w for w in cfg.workloads if w not in known]
    if unknown:
        print(f"error: unknown workload(s) {unknown}; see "
              "python -m repro.tune --list", file=sys.stderr)
        return 2
    payload = run_experiments(cfg)

    print(format_table(payload))
    if args.out:
        print(f"\nwrote {args.out}")

    s = payload["summary"]
    if s["deterministic"] is False:
        print("FAIL: same-seed rerun or LLM replay diverged",
              file=sys.stderr)
        return 1
    if args.min_wins is not None and s["asi_wins"] < args.min_wins:
        print(f"FAIL: ASI beat every scalar baseline on only "
              f"{s['asi_wins']} workloads (< {args.min_wins})",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
