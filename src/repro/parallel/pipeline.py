"""Pipeline parallelism: a GPipe-style stage executor on a "stage" mesh
axis, using shard_map + ppermute for the inter-stage transfers.

The DSL binds here through ``Task <stage> PP;``: layers are split into
``n_stages`` contiguous groups; microbatches stream through stages with
the classic GPipe schedule (bubble fraction (S-1)/(M+S-1)).  Forward-only
(serving / evaluation) and trainable (jax.grad-through-shard_map) paths
are both supported; numerics equal the unpipelined stack (tested).

This is the third axis of DP x TP x PP for 1000+-node scale: the
production meshes here are 2D (+pod), so pipeline stages ride the "data"
axis when enabled -- `make_pipeline_mesh` builds (stage, data, model)
views of the same devices.
"""

from __future__ import annotations

import functools
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def make_pipeline_mesh(n_stages: int, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    assert n % n_stages == 0, (n, n_stages)
    arr = np.array(devices).reshape(n_stages, n // n_stages)
    return Mesh(arr, ("stage", "data"))


def pipeline_forward(stage_fn: Callable, params_stacked, x,
                     mesh: Mesh, n_microbatches: int):
    """Run ``y = stage_{S-1}(... stage_0(x))`` with GPipe streaming.

    stage_fn(stage_params, h) -> h applies ONE stage.
    params_stacked: pytree with leading dim n_stages (stage-sharded).
    x: [M, mb, ...] microbatched input, replicated over stages.
    Returns y with the same layout as x.
    """
    n_stages = mesh.shape["stage"]
    m = n_microbatches
    steps = m + n_stages - 1

    def kernel(p_stage, xs):
        # p_stage: this stage's params (leading dim 1); xs: [M, mb, ...]
        sid = jax.lax.axis_index("stage")
        p_local = jax.tree.map(lambda a: a[0], p_stage)
        mb_shape = xs.shape[1:]
        buf = jnp.zeros_like(xs)            # collected outputs
        carry = jnp.zeros(mb_shape, xs.dtype)  # inter-stage register

        def step(t, state):
            carry, buf = state
            # stage 0 ingests microbatch t (when in range)
            mb_in = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, m - 1), 0, keepdims=False)
            h_in = jnp.where(sid == 0, mb_in, carry)
            h_out = stage_fn(p_local, h_in)
            # last stage emits microbatch t - (S-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            is_valid = (t - (n_stages - 1) >= 0) & (sid == n_stages - 1)
            buf = jax.lax.cond(
                is_valid,
                lambda b: jax.lax.dynamic_update_index_in_dim(
                    b, h_out.astype(b.dtype), out_idx, 0),
                lambda b: b, buf)
            # rotate: stage s sends h_out to stage s+1
            nxt = jax.lax.ppermute(
                h_out, "stage",
                [(s, (s + 1) % n_stages) for s in range(n_stages)])
            return nxt, buf

        carry, buf = jax.lax.fori_loop(0, steps, step, (carry, buf))
        # buf is zeros except on the last stage: psum = broadcast.
        return jax.lax.psum(buf, "stage")

    y = shard_map(
        kernel, mesh=mesh,
        in_specs=(P("stage"), P()),
        out_specs=P(),
        check_rep=False,
    )(params_stacked, x)
    return y
