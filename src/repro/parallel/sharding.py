"""Logical-axis sharding: the bridge between MappingPlans and pjit.

Models annotate parameters and activations with *logical* axis names
("batch", "seq", "heads", "ffn", "experts", ...).  An :class:`AxisRules`
object -- produced by compiling a DSL mapper, or by the expert default --
maps each logical axis to zero or more *mesh* axes.  Everything else
(`PartitionSpec` construction, constraint application, conflict checking)
lives here.

Rules are installed with the ``axis_rules(rules)`` context manager; model
code calls ``logical_constraint(x, ("batch", "seq", "d_model"))`` without
knowing the mesh.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# The expert-written default rules (= the "expert mapper" baseline for LMs):
# FSDP over the data axis + tensor parallelism over the model axis.
DEFAULT_TRAIN_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "d_model": ("data",),        # FSDP shard of the weight "reduction" dim
    "d_model_out": ("data",),
    "act_d": None,               # activation feature dim: replicated
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "ffn": ("model",),
    "experts": ("model",),
    "expert_ffn": ("model",),
    "vocab": ("model",),
    "state": None,
    "conv": None,
    "rnn": ("model",),
    "layers": None,
    "act_seq": None,             # sequence sharding of activations (SP)
    "cache_batch": ("data",),
    "cache_seq": ("model",),     # decode-time context parallelism
    "cache_heads": None,
}


@dataclass
class AxisRules:
    """logical axis -> mesh axes, plus global knobs the plan controls."""

    rules: Dict[str, MeshAxes] = field(default_factory=dict)
    mesh: Optional[Mesh] = None
    # Remat policy for the layer scan: "none" | "block" | "full"
    remat: str = "block"
    # Microbatch count for gradient accumulation (1 = no accumulation).
    microbatches: int = 1
    # Layout choices (from DSL Layout stmts), keyed by tensor role.
    layouts: Dict[str, object] = field(default_factory=dict)
    # Placement overrides, keyed by tensor role: SHARD | REPL | REMAT | HOST
    placements: Dict[str, str] = field(default_factory=dict)

    def _axis_size(self, name: str) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[name]

    def resolve(self, axes: Sequence[Optional[str]],
                shape: Optional[Sequence[int]] = None) -> P:
        """Logical axes tuple -> PartitionSpec.

        Drops unknown axes, de-duplicates mesh axes (first occurrence wins)
        and -- when ``shape`` is given -- drops mesh axes that do not divide
        the dimension (e.g. 8 KV heads cannot shard over model=16: the KV
        tensors fall back to replication, the GQA semantics on TPU)."""
        used = set()
        parts = []
        for d, ax in enumerate(axes):
            if ax is None:
                parts.append(None)
                continue
            tgt = self.rules.get(ax)
            if tgt is None:
                parts.append(None)
                continue
            if isinstance(tgt, str):
                tgt = (tgt,)
            tgt = tuple(t for t in tgt if t not in used
                        and (self.mesh is None or t in self.mesh.axis_names))
            if shape is not None and tgt:
                kept = []
                prod = 1
                for t in tgt:
                    n = self._axis_size(t) * prod
                    if shape[d] % n == 0:
                        kept.append(t)
                        prod = n
                tgt = tuple(kept)
            used.update(tgt)
            if not tgt:
                parts.append(None)
            elif len(tgt) == 1:
                parts.append(tgt[0])
            else:
                parts.append(tuple(tgt))
        return P(*parts)

    def sharding(self, axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None
                 ) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.resolve(axes, shape))

    def with_updates(self, **updates) -> "AxisRules":
        new_rules = dict(self.rules)
        new_rules.update(updates.pop("rules", {}))
        out = AxisRules(rules=new_rules, mesh=updates.pop("mesh", self.mesh),
                        remat=updates.pop("remat", self.remat),
                        microbatches=updates.pop("microbatches",
                                                 self.microbatches),
                        layouts=dict(self.layouts), placements=dict(self.placements))
        for k, v in updates.items():
            setattr(out, k, v)
        return out


_STATE = threading.local()


def current_rules() -> Optional[AxisRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: AxisRules):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def logical_constraint(x, axes: Sequence[Optional[str]]):
    """Apply a sharding constraint expressed in logical axes (no-op when no
    rules/mesh are installed, so models run unmodified on one device)."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    aval = jax.eval_shape(lambda v: v, x)
    if aval.ndim != len(axes):  # defensive
        return x
    return jax.lax.with_sharding_constraint(x, r.sharding(axes, aval.shape))


def logical_to_pspec(axes: Sequence[Optional[str]],
                     rules: Optional[AxisRules] = None,
                     shape: Optional[Sequence[int]] = None) -> P:
    r = rules or current_rules()
    if r is None:
        return P()
    return r.resolve(axes, shape)


def _is_axes_leaf(v) -> bool:
    return isinstance(v, tuple) and all(
        isinstance(a, (str, type(None))) for a in v)


def param_shardings(axes_tree, rules: AxisRules, abstract_tree=None):
    """Map a pytree of logical-axes tuples to NamedShardings.

    With ``abstract_tree`` (matching ShapeDtypeStructs), per-dim
    divisibility is enforced."""
    if abstract_tree is None:
        return jax.tree.map(lambda axes: rules.sharding(axes), axes_tree,
                            is_leaf=_is_axes_leaf)
    return jax.tree.map(
        lambda axes, a: rules.sharding(axes, a.shape),
        axes_tree, abstract_tree, is_leaf=_is_axes_leaf)
