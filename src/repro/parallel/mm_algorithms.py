"""Distributed matrix-multiplication algorithms (paper §5.3) as shard_map
programs, plus the communication model the mapper search optimizes.

Algorithms (all numerically validated against jnp.dot in tests):

  cannon      2D systolic: skew + p shift/multiply rounds  (Cannon 1969)
  summa       2D: gather row-of-A / col-of-B, local k-loop (vdG & Watts 97)
  pumma       2D: ring-pipelined column broadcasts          (Choi et al. 94)
  johnson     3D: one matmul + reduce over the k axis       (Agarwal 95)
  solomonik   2.5D: c stacked Cannon replicas on K/c slices (Solomonik 11)
  cosma       grid-optimal generic (gm, gn, gk) decomposition minimizing
              per-device communication under a memory budget (COSMA 19)

The *index mapping* (DSL ``IndexTaskMap``) decides which tile lands on
which physical chip.  ``comm_model`` scores a (algorithm, mapping) pair by
bytes x torus-hops -- the deterministic objective the paper's agent
optimizes ("the optimized mapping reduces inter-GPU communication").
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


# ---------------------------------------------------------------------------
# shard_map implementations
# ---------------------------------------------------------------------------
def _mesh2(mesh: Mesh) -> Tuple[str, str]:
    return mesh.axis_names[-2], mesh.axis_names[-1]


def _to_varying(x: jax.Array, axes: Tuple[str, ...]) -> jax.Array:
    """Mark a replicated per-shard value as varying over ``axes``.

    jax >= 0.6 spells this ``lax.pcast(..., to='varying')`` (earlier
    ``lax.pvary``); on older releases the rep checker joins replicated
    and varying values implicitly, so identity is correct."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axes, to="varying")
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axes)
    return x


def cannon_mm(A: jax.Array, B: jax.Array, mesh: Mesh) -> jax.Array:
    """Cannon's algorithm on a square (p, p) mesh."""
    ax, ay = _mesh2(mesh)
    px, py = mesh.shape[ax], mesh.shape[ay]
    assert px == py, "Cannon requires a square grid"
    p = px

    def kernel(a, b):
        # initial skew: A_ij <- A_i,(j+i);  B_ij <- B_(i+j),j
        # (coordinate-dependent shift = full-grid permutation)
        def skew(x, by_row: bool):
            perm = []
            for i0 in range(p):
                for j0 in range(p):
                    if by_row:      # shift row i left by i
                        src = (i0, (j0 + i0) % p)
                    else:           # shift col j up by j
                        src = ((i0 + j0) % p, j0)
                    perm.append((src[0] * p + src[1], i0 * p + j0))
            return jax.lax.ppermute(x, (ax, ay), perm)

        a = skew(a, True)
        b = skew(b, False)
        c = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
        c = _to_varying(c, (ax, ay))

        shift_a = [((i0 * p + (j0 + 1) % p), i0 * p + j0)
                   for i0 in range(p) for j0 in range(p)]
        shift_b = [((((i0 + 1) % p) * p + j0), i0 * p + j0)
                   for i0 in range(p) for j0 in range(p)]

        def body(step, carry):
            a, b, c = carry
            c = c + jnp.dot(a, b, preferred_element_type=jnp.float32)
            a = jax.lax.ppermute(a, (ax, ay), shift_a)
            b = jax.lax.ppermute(b, (ax, ay), shift_b)
            return a, b, c

        a, b, c = jax.lax.fori_loop(0, p, body, (a, b, c))
        return c.astype(A.dtype)

    return shard_map(kernel, mesh=mesh,
                     in_specs=(P(ax, ay), P(ax, ay)),
                     out_specs=P(ax, ay))(A, B)


def summa_mm(A: jax.Array, B: jax.Array, mesh: Mesh) -> jax.Array:
    """SUMMA: gather the A-row / B-column panels, loop over k blocks."""
    ax, ay = _mesh2(mesh)
    py = mesh.shape[ay]
    px = mesh.shape[ax]

    def kernel(a, b):
        a_row = jax.lax.all_gather(a, ay, axis=1, tiled=True)  # [mb, K]
        b_col = jax.lax.all_gather(b, ax, axis=0, tiled=True)  # [K, nb]
        kb = a_row.shape[1] // (px * py)
        c = jnp.zeros((a_row.shape[0], b_col.shape[1]), jnp.float32)
        c = _to_varying(c, (ax, ay))

        def body(k, c):
            ak = jax.lax.dynamic_slice_in_dim(a_row, k * kb, kb, 1)
            bk = jax.lax.dynamic_slice_in_dim(b_col, k * kb, kb, 0)
            return c + jnp.dot(ak, bk, preferred_element_type=jnp.float32)

        c = jax.lax.fori_loop(0, px * py, body, c)
        return c.astype(A.dtype)

    return shard_map(kernel, mesh=mesh,
                     in_specs=(P(ax, ay), P(ax, ay)),
                     out_specs=P(ax, ay))(A, B)


def pumma_mm(A: jax.Array, B: jax.Array, mesh: Mesh) -> jax.Array:
    """PUMMA-style: ring-pipelined panel rotation instead of gathers.

    Each of the py rounds rotates the local A panel along the row ring and
    the local B panel along the column ring, accumulating the aligned
    products (block-cyclic pipelining of SUMMA's broadcasts).
    """
    ax, ay = _mesh2(mesh)
    px, py = mesh.shape[ax], mesh.shape[ay]
    assert px == py, "pumma (this schedule) requires a square grid"
    p = px

    def kernel(a, b):
        i = jax.lax.axis_index(ax)
        j = jax.lax.axis_index(ay)
        # Pre-align like Cannon so round r multiplies A_{i,i+j+r} B_{i+j+r,j}
        def skew(x, by_row: bool):
            perm = []
            for i0 in range(p):
                for j0 in range(p):
                    src = ((i0, (j0 + i0) % p) if by_row
                           else ((i0 + j0) % p, j0))
                    perm.append((src[0] * p + src[1], i0 * p + j0))
            return jax.lax.ppermute(x, (ax, ay), perm)

        a = skew(a, True)
        b = skew(b, False)
        ring_a = [((i0 * p + (j0 + 1) % p), i0 * p + j0)
                  for i0 in range(p) for j0 in range(p)]
        ring_b = [((((i0 + 1) % p) * p + j0), i0 * p + j0)
                  for i0 in range(p) for j0 in range(p)]
        c = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
        c = _to_varying(c, (ax, ay))

        def body(step, carry):
            a, b, c = carry
            c = c + jnp.dot(a, b, preferred_element_type=jnp.float32)
            # pipelined: rotate panels one hop per round (double-buffered
            # on real hardware; the volume is what the model scores)
            a = jax.lax.ppermute(a, (ax, ay), ring_a)
            b = jax.lax.ppermute(b, (ax, ay), ring_b)
            return a, b, c

        _, _, c = jax.lax.fori_loop(0, p, body, (a, b, c))
        return c.astype(A.dtype)

    return shard_map(kernel, mesh=mesh,
                     in_specs=(P(ax, ay), P(ax, ay)),
                     out_specs=P(ax, ay))(A, B)


def grid_mm(A: jax.Array, B: jax.Array, mesh3: Mesh) -> jax.Array:
    """Generic (gm, gn, gk) grid algorithm: local matmul + reduce over k.

    Johnson's 3D algorithm is grid (p^1/3, p^1/3, p^1/3); COSMA picks the
    comm-optimal grid for the given shapes; Solomonik's uses (p, p, c) with
    a Cannon schedule inside each k-slice.
    """
    am, an, ak = mesh3.axis_names

    def kernel(a, b):
        c = jnp.dot(a, b, preferred_element_type=jnp.float32)
        c = jax.lax.psum(c, ak)
        return c.astype(A.dtype)

    return shard_map(kernel, mesh=mesh3,
                     in_specs=(P(am, ak), P(ak, an)),
                     out_specs=P(am, an))(A, B)


def johnson_mm(A, B, mesh3: Mesh):
    gm = mesh3.shape[mesh3.axis_names[0]]
    gn = mesh3.shape[mesh3.axis_names[1]]
    gk = mesh3.shape[mesh3.axis_names[2]]
    assert gm == gn == gk, "Johnson's 3D algorithm needs a cubic grid"
    return grid_mm(A, B, mesh3)


def solomonik_mm(A: jax.Array, B: jax.Array, mesh3: Mesh) -> jax.Array:
    """2.5D: c replicas each run Cannon on a K/c slice, then reduce."""
    ac, ax, ay = mesh3.axis_names
    p = mesh3.shape[ax]
    assert mesh3.shape[ax] == mesh3.shape[ay]

    def kernel(a, b):
        # within this k-slice: Cannon over the (ax, ay) square
        def skew(x, by_row: bool):
            perm = []
            for i0 in range(p):
                for j0 in range(p):
                    src = ((i0, (j0 + i0) % p) if by_row
                           else ((i0 + j0) % p, j0))
                    perm.append((src[0] * p + src[1], i0 * p + j0))
            return jax.lax.ppermute(x, (ax, ay), perm)

        a = skew(a, True)
        b = skew(b, False)
        ring_a = [((i0 * p + (j0 + 1) % p), i0 * p + j0)
                  for i0 in range(p) for j0 in range(p)]
        ring_b = [((((i0 + 1) % p) * p + j0), i0 * p + j0)
                  for i0 in range(p) for j0 in range(p)]
        c = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
        c = _to_varying(c, (ac, ax, ay))

        def body(step, carry):
            a, b, c = carry
            c = c + jnp.dot(a, b, preferred_element_type=jnp.float32)
            a = jax.lax.ppermute(a, (ax, ay), ring_a)
            b = jax.lax.ppermute(b, (ax, ay), ring_b)
            return a, b, c

        _, _, c = jax.lax.fori_loop(0, p, body, (a, b, c))
        c = jax.lax.psum(c, ac)
        return c.astype(A.dtype)

    return shard_map(kernel, mesh=mesh3,
                     in_specs=(P(ax, (ac, ay)), P((ac, ax), ay)),
                     out_specs=P(ax, ay))(A, B)


def cosma_grid(P_: int, M: int, N: int, K: int,
               mem_tiles: float = 3.0) -> Tuple[int, int, int]:
    """COSMA-style grid choice: minimize per-device comm volume
    V(g) = MK/(gm gk) + KN/(gk gn) + MN/(gm gn) over divisor grids of P."""
    best, best_v = (P_, 1, 1), float("inf")
    for gm in range(1, P_ + 1):
        if P_ % gm:
            continue
        rest = P_ // gm
        for gn in range(1, rest + 1):
            if rest % gn:
                continue
            gk = rest // gn
            v = M * K / (gm * gk) + K * N / (gk * gn) + M * N / (gm * gn)
            # memory: replicas of A and B tiles must fit mem_tiles x ideal
            mem = M * K / (gm * gk) + K * N / (gk * gn) + M * N / (gm * gn)
            if mem > mem_tiles * (M * K + K * N + M * N) / P_:
                continue
            if v < best_v:
                best, best_v = (gm, gn, gk), v
    return best


ALGORITHMS = ("cannon", "summa", "pumma", "johnson", "solomonik", "cosma")


def run_algorithm(name: str, A, B, devices=None,
                  grid: Optional[Tuple[int, ...]] = None):
    """Dispatch: build the right mesh over ``devices`` and run."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    darr = np.array(devices)
    if name in ("cannon", "summa", "pumma"):
        p = int(math.isqrt(n))
        if name != "summa":
            assert p * p == n, f"{name} needs a square device count"
        if p * p != n:
            px = int(math.isqrt(n))
            while n % px:
                px -= 1
            mesh = Mesh(darr[: px * (n // px)].reshape(px, n // px), ("x", "y"))
        else:
            mesh = Mesh(darr.reshape(p, p), ("x", "y"))
        fn = {"cannon": cannon_mm, "summa": summa_mm, "pumma": pumma_mm}[name]
        return fn(A, B, mesh)
    if name == "johnson":
        g = round(n ** (1 / 3))
        assert g ** 3 == n, "johnson needs a cubic device count"
        mesh = Mesh(darr.reshape(g, g, g), ("gm", "gn", "gk"))
        return johnson_mm(A, B, mesh)
    if name == "solomonik":
        # (c, p, p) with c = n / p^2 for largest square p^2 | n
        p = int(math.isqrt(n))
        while n % (p * p):
            p -= 1
        c = n // (p * p)
        mesh = Mesh(darr.reshape(c, p, p), ("c", "x", "y"))
        return solomonik_mm(A, B, mesh)
    if name == "cosma":
        M, K = A.shape
        N = B.shape[1]
        gm, gn, gk = grid or cosma_grid(n, M, N, K)
        mesh = Mesh(darr.reshape(gm, gn, gk), ("gm", "gn", "gk"))
        return grid_mm(A, B, mesh)
    raise KeyError(name)


# ---------------------------------------------------------------------------
# Communication model: bytes x torus hops under a tile->device mapping
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TorusTopo:
    shape: Tuple[int, int]        # physical (nodes, chips) torus

    def coords(self, flat: int) -> Tuple[int, int]:
        return flat // self.shape[1], flat % self.shape[1]

    def hops(self, a: int, b: int) -> int:
        if a == b:
            return 0
        (ax, ay), (bx, by) = self.coords(a), self.coords(b)
        dx = abs(ax - bx)
        dx = min(dx, self.shape[0] - dx)
        dy = abs(ay - by)
        dy = min(dy, self.shape[1] - dy)
        # inter-node hop is the expensive link; weight it 4x
        return 4 * dx + dy


def _transfers(alg: str, p: int, grid: Tuple[int, int, int] = None):
    """Yield (src_tile, dst_tile, tile_kind) logical transfer events for
    one full run, in tile coordinates of the algorithm's grid."""
    events = []
    if alg in ("cannon", "pumma", "solomonik"):
        for i in range(p):
            for j in range(p):
                # skew + p ring steps for both A and B
                events.append(((i, (j + i) % p), (i, j), "A"))
                events.append((((i + j) % p, j), (i, j), "B"))
                for _ in range(p - 1):
                    events.append(((i, (j + 1) % p), (i, j), "A"))
                    events.append((((i + 1) % p, j), (i, j), "B"))
    elif alg == "summa":
        for i in range(p):
            for j in range(p):
                for k in range(p):
                    if k != j:
                        events.append(((i, k), (i, j), "A"))
                    if k != i:
                        events.append(((k, j), (i, j), "B"))
    elif alg in ("johnson", "cosma"):
        gm, gn, gk = grid
        # replication of A over gn, B over gm, reduction over gk.  An input
        # tile's initial owner is the iteration point with the zero
        # coordinate on the replicated axis (canonical 3D placement).
        for im in range(gm):
            for jn in range(gn):
                for kk in range(gk):
                    events.append(((im, 0, kk), (im, jn, kk), "A"))
                    events.append(((0, jn, kk), (im, jn, kk), "B"))
                    if kk:
                        events.append(((im, jn, kk), (im, jn, 0), "C"))
    return events


def comm_model(alg: str, M: int, N: int, K: int, n_devices: int,
               tile_to_device: Callable[[Tuple[int, ...]], int],
               topo: TorusTopo, dtype_bytes: int = 2,
               flops_per_s: float = 197e12, bw: float = 50e9) -> Dict:
    """Estimated execution time of (algorithm, index-mapping) on the torus.

    tile_to_device maps a tile coordinate (the algorithm's iteration space)
    to a physical flat device id -- this is exactly what the DSL's
    IndexTaskMap chooses, and the searchable quantity of paper §5.3.
    """
    if alg in ("cannon", "summa", "pumma"):
        p = int(math.isqrt(n_devices))
        grid = (p, p, 1)
        tile_bytes = {"A": M * K // (p * p) * dtype_bytes,
                      "B": K * N // (p * p) * dtype_bytes,
                      "C": M * N // (p * p) * dtype_bytes}
        events = _transfers(alg, p)
    elif alg == "solomonik":
        p = int(math.isqrt(n_devices))
        while n_devices % (p * p):
            p -= 1
        grid = (p, p, n_devices // (p * p))
        tile_bytes = {"A": M * K // (p * p * grid[2]) * dtype_bytes,
                      "B": K * N // (p * p * grid[2]) * dtype_bytes,
                      "C": M * N // (p * p) * dtype_bytes}
        events = _transfers("solomonik", p)
    else:
        if alg == "johnson":
            g = round(n_devices ** (1 / 3))
            grid = (g, g, g)
        else:
            grid = cosma_grid(n_devices, M, N, K)
        gm, gn, gk = grid
        tile_bytes = {"A": M * K // (gm * gk) * dtype_bytes,
                      "B": K * N // (gk * gn) * dtype_bytes,
                      "C": M * N // (gm * gn) * dtype_bytes}
        events = _transfers(alg, 0, grid)

    total_cost = 0.0
    per_dev: Dict[int, float] = {}
    tiles_on: Dict[int, int] = {}
    seen_tiles = set()
    for src_tile, dst_tile, kind in events:
        s = tile_to_device(src_tile)
        d = tile_to_device(dst_tile)
        if dst_tile not in seen_tiles:
            seen_tiles.add(dst_tile)
            tiles_on[d] = tiles_on.get(d, 0) + 1
        h = topo.hops(s % n_devices, d % n_devices)
        cost = tile_bytes[kind] * h
        total_cost += cost
        per_dev[d] = per_dev.get(d, 0.0) + cost
    max_dev_cost = max(per_dev.values()) if per_dev else 0.0

    # compute time follows the actual tile->device assignment: a device
    # executing t tiles serializes them (degenerate all-on-one mappings
    # pay full serialization, not free parallelism).
    n_tiles = len(seen_tiles) if seen_tiles else 1
    gm, gn, gk = grid
    if alg in ("cannon", "summa", "pumma", "solomonik"):
        flops_tile = 2.0 * M * N * K / (gm * gn)       # each tile runs full K
        if alg == "solomonik":
            flops_tile = 2.0 * M * N * K / (gm * gn * gk)
    else:
        flops_tile = 2.0 * M * N * K / (gm * gn * gk)
    max_tiles = max(tiles_on.values()) if tiles_on else 1
    compute_s = max_tiles * flops_tile / flops_per_s
    comm_s = max_dev_cost / bw
    return {
        "compute_s": compute_s,
        "comm_s": comm_s,
        "time_s": max(compute_s, comm_s) + 0.2 * min(compute_s, comm_s),
        "total_bytes_hops": total_cost,
        "grid": grid,
    }
