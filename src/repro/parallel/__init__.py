from .sharding import (
    AxisRules, axis_rules, current_rules, logical_constraint,
    logical_to_pspec, param_shardings, DEFAULT_TRAIN_RULES,
)

__all__ = [
    "AxisRules", "axis_rules", "current_rules", "logical_constraint",
    "logical_to_pspec", "param_shardings", "DEFAULT_TRAIN_RULES",
]
