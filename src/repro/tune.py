"""CLI front door for the Agent-System Interface.

    python -m repro.tune --list
    python -m repro.tune --list --substrate matmul
    python -m repro.tune --workload circuit --strategy trace --iters 10
    python -m repro.tune --workload matmul/summa --batch 4 --out traj.json
    python -m repro.tune --workload circuit --feedback-level scalar
    python -m repro.tune --workload circuit --checkpoint sess.json
    python -m repro.tune --resume sess.json --iters 20
    python -m repro.tune --workload kernel/block_matmul --tier measured

``--feedback-level`` ablates how much of the AutoGuide ExecutionReport
the optimizer sees (paper Fig. 8): scalar | system | explain | full.
"""

from __future__ import annotations

import argparse
import json
import math
import sys


def _jsonable(x):
    """Strict-JSON scalar: non-finite floats become null."""
    if isinstance(x, float) and not math.isfinite(x):
        return None
    return x


def _print_listing(substrate=None):
    from .asi import registry
    infos = [i for i in registry.populate().list()
             if substrate is None or i.substrate == substrate]
    by_sub = {}
    for i in infos:
        by_sub.setdefault(i.substrate, []).append(i)
    print(f"{len(infos)} registered workloads "
          f"({len(by_sub)} substrates)")
    for sub in sorted(by_sub):
        print(f"\n[{sub}]")
        for i in by_sub[sub]:
            print(f"  {i.name:40s} {i.description}")


def _result_payload(res, args):
    return {
        "workload": args.workload,
        "strategy": args.strategy,
        "iterations": args.iters,
        "batch": args.batch,
        "seed": args.seed,
        "best_score": _jsonable(res.best_score),
        "best_decisions": res.best_decisions,
        "best_mapper": res.best_mapper,
        "trajectory": [_jsonable(t) for t in res.trajectory],
        "evaluations": len(res.graph.records),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="Tune a registered workload through the unified "
                    "Agent-System Interface.")
    ap.add_argument("--list", action="store_true",
                    help="list registered workloads and exit")
    ap.add_argument("--substrate", default=None,
                    help="filter --list by substrate (lm, app, app-jax, "
                         "matmul)")
    from .asi.tuner import STRATEGIES

    ap.add_argument("--workload", default=None,
                    help="registry name, e.g. circuit or matmul/summa or "
                         "lm/stablelm-1.6b/train_4k")
    ap.add_argument("--strategy", default=None, choices=STRATEGIES,
                    help="(default: trace)")
    ap.add_argument("--iters", type=int, default=None,
                    help="iteration target (default: 10, or the "
                         "checkpoint's own target when resuming)")
    ap.add_argument("--batch", type=int, default=None,
                    help="candidates proposed+evaluated per iteration "
                         "(default: 1)")
    ap.add_argument("--seed", type=int, default=None, help="(default: 0)")
    ap.add_argument("--feedback-level", default=None,
                    choices=("scalar", "system", "explain", "full"),
                    help="how much of the ExecutionReport the optimizer "
                         "sees, Fig. 8 ablation (default: full)")
    ap.add_argument("--tier", default=None,
                    choices=("analytic", "measured"),
                    help="evaluation tier: 'measured' wall-clocks every "
                         "candidate (Tier 3) on workloads that support it "
                         "(kernel/*, smoke LM cells); default: the "
                         "workload's own")
    ap.add_argument("--checkpoint", default=None,
                    help="write a resumable JSON session here every "
                         "iteration")
    ap.add_argument("--record-llm", default=None, metavar="LOG",
                    help="capture every LLM proposal exchange to this "
                         "JSON log (replayable via --replay-llm)")
    ap.add_argument("--replay-llm", default=None, metavar="LOG",
                    help="drive the run from a recorded proposal log, "
                         "bit-for-bit (fails loudly on divergence)")
    ap.add_argument("--resume", default=None, metavar="CHECKPOINT",
                    help="resume a checkpointed session")
    ap.add_argument("--warm-start", default=None, metavar="STORE",
                    help="seed opening candidates from the nearest "
                         "neighbor cells' best artifacts in this "
                         "MapperStore (see repro.meta)")
    ap.add_argument("--warm-k", type=int, default=3,
                    help="neighbor cells to seed from (default: 3)")
    ap.add_argument("--learned-pack", default=None, metavar="PACK.json",
                    help="compose this validated LearnedPack into the "
                         "workload's diagnostics for the run")
    ap.add_argument("--out", default=None,
                    help="write the result (trajectory, best mapper) as "
                         "JSON here instead of stdout")
    args = ap.parse_args(argv)

    if args.list:
        _print_listing(args.substrate)
        return 0

    from .asi import Tuner, tune

    try:
        if args.resume:
            # a session resumes with its own settings; conflicting flags
            # would silently break the deterministic-resume guarantee
            fixed = [f"--{n}" for n, v in
                     [("strategy", args.strategy), ("batch", args.batch),
                      ("seed", args.seed),
                      ("feedback-level", args.feedback_level),
                      ("tier", args.tier),
                      ("checkpoint", args.checkpoint),
                      ("record-llm", args.record_llm),
                      ("replay-llm", args.replay_llm),
                      ("warm-start", args.warm_start),
                      ("learned-pack", args.learned_pack),
                      ("workload", args.workload)] if v is not None]
            if fixed:
                ap.error(f"--resume takes these from the checkpoint; "
                         f"drop {', '.join(fixed)}")
            tuner = Tuner.from_checkpoint(args.resume,
                                          iterations=args.iters)
            args.workload = tuner.workload.name
            args.strategy = tuner.strategy
            args.batch = tuner.batch
            args.seed = tuner.seed
            args.iters = tuner.iterations
            res = tuner.resume()
        elif args.workload:
            args.iters = 10 if args.iters is None else args.iters
            args.strategy = args.strategy or "trace"
            args.batch = 1 if args.batch is None else args.batch
            args.seed = 0 if args.seed is None else args.seed
            if args.record_llm and args.replay_llm:
                ap.error("--record-llm and --replay-llm are mutually "
                         "exclusive")
            llm = recorder = None
            if args.replay_llm:
                from .core.agent.llm import ReplayLLM
                llm = ReplayLLM.load(args.replay_llm)
            elif args.record_llm:
                from .asi import registry
                from .core.agent.llm import RecordingLLM
                llm = recorder = RecordingLLM(
                    registry.get(args.workload).llm())
            target = args.workload
            seeds = None
            if args.learned_pack:
                from .asi import registry
                from .meta import LearnedPack, register_pack, with_pack
                pack = LearnedPack.load(args.learned_pack)
                register_pack(pack)     # refuses unvalidated packs
                target = with_pack(registry.get(args.workload), pack)
                print(f"composed learned pack {pack.name!r} "
                      f"({len(pack.rules)} rules) into diagnostics",
                      file=sys.stderr)
            if args.warm_start:
                from .asi import registry
                from .meta import warm_start_candidates
                wl = target if not isinstance(target, str) \
                    else registry.get(target)
                seeds = warm_start_candidates(wl, args.warm_start,
                                              k=args.warm_k)
                names = [s["from"]["workload"] for s in seeds]
                print(f"warm start: {len(seeds)} seed candidate(s) "
                      f"from {names}" if seeds else
                      "warm start: no transferable neighbors found",
                      file=sys.stderr)
            res = tune(target, strategy=args.strategy,
                       iterations=args.iters, batch=args.batch,
                       seed=args.seed,
                       feedback_level=args.feedback_level or "full",
                       checkpoint=args.checkpoint, llm=llm,
                       tier=args.tier, seed_candidates=seeds or None)
            if recorder is not None:
                recorder.save(args.record_llm)
                print(f"recorded {len(recorder.calls)} LLM proposals "
                      f"-> {args.record_llm}", file=sys.stderr)
        else:
            ap.error("one of --list, --workload, or --resume is required")
            return 2
    except (KeyError, ValueError) as e:
        print(f"error: {e.args[0] if e.args else e}", file=sys.stderr)
        return 2
    except OSError as e:
        print(f"error: cannot read checkpoint: {e}", file=sys.stderr)
        return 2

    payload = _result_payload(res, args)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.out}")
    else:
        print(f"workload:  {payload['workload']}")
        print(f"strategy:  {payload['strategy']} (batch={payload['batch']}, "
              f"seed={payload['seed']})")
        print(f"evaluated: {payload['evaluations']} candidates over "
              f"{len(payload['trajectory'])} iterations")
        best = payload["best_score"]
        print(f"best:      "
              f"{'no valid candidate' if best is None else f'{best:.6f}s'}")
        print("trajectory (best-so-far):")
        print("  " + " ".join("inf" if t is None else f"{t:.4g}"
                              for t in payload["trajectory"]))
        print("best mapper:\n" + payload["best_mapper"])
    return 0


if __name__ == "__main__":
    sys.exit(main())
