"""CLI for the meta-optimization layer.

    python -m repro.meta mine --store store.db --checkpoints runs/
    python -m repro.meta distill --store store.db --out pack.json
    python -m repro.meta validate --pack pack.json --workloads circuit stencil
    python -m repro.meta warm-start --store store.db --workload cannon
    python -m repro.meta meta-tune --workloads circuit --iters 6

``validate`` exits non-zero when the pack fails the held-out gate, so a
distill->validate pipeline can be scripted; ``warm-start`` prints the
seed candidates (add ``--tune`` to actually run the warm-started loop
and compare against cold start).
"""

from __future__ import annotations

import argparse
import json
import sys

from .learned import LearnedPack, distill_pack, validate_pack
from .metatune import MetaConfig, iterations_to_beat, meta_tune
from .mine import mine_traces
from .warmstart import warm_start_candidates


def _dataset(args):
    return mine_traces(store=args.store,
                       checkpoints=tuple(args.checkpoints or ()))


def _cmd_mine(args) -> int:
    ds = _dataset(args)
    out = ds.summary()
    out["win_patterns"] = ds.win_patterns(min_support=args.min_support)
    out["fix_patterns"] = ds.fix_patterns(min_support=args.min_support)
    print(json.dumps(out, indent=2))
    return 0


def _cmd_distill(args) -> int:
    pack = distill_pack(_dataset(args), name=args.name,
                        min_support=args.min_support,
                        min_lift=args.min_lift, max_rules=args.max_rules)
    pack.save(args.out)
    print(f"distilled {len(pack.rules)} rule(s) -> {args.out} "
          f"(unvalidated; run `python -m repro.meta validate`)")
    return 0


def _cmd_validate(args) -> int:
    pack = LearnedPack.load(args.pack)
    verdict = validate_pack(pack, args.workloads, strategy=args.strategy,
                            iterations=args.iters, seed=args.seed)
    pack.save(args.pack)           # persist the verdict with the pack
    print(json.dumps(verdict, indent=2))
    return 0 if verdict["passed"] else 1


def _cmd_warm_start(args) -> int:
    from ..asi import registry, tune
    wl = registry.get(args.workload)
    seeds = warm_start_candidates(wl, args.store, k=args.k)
    report = {"workload": args.workload,
              "candidates": [{"from": s["from"]} for s in seeds]}
    if not seeds:
        print(json.dumps(report, indent=2))
        print("no transferable neighbors found", file=sys.stderr)
        return 1
    if args.tune:
        from ..experiments import expert_score
        bar = expert_score(args.workload)
        cold = tune(wl, strategy=args.strategy, iterations=args.iters,
                    seed=args.seed)
        warm = tune(wl, strategy=args.strategy, iterations=args.iters,
                    seed=args.seed, seed_candidates=seeds)
        report["expert_score"] = bar
        report["cold"] = {"best": cold.best_score,
                          "iterations_to_beat":
                              iterations_to_beat(cold.trajectory, bar)}
        report["warm"] = {"best": warm.best_score,
                          "iterations_to_beat":
                              iterations_to_beat(warm.trajectory, bar)}
    print(json.dumps(report, indent=2))
    return 0


def _cmd_meta_tune(args) -> int:
    result = meta_tune(args.workloads, strategy=args.strategy,
                       iterations=args.iters, seeds=tuple(args.seeds))
    print(json.dumps(result.to_dict(), indent=2))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.meta",
        description="Mine tuning history; distill, validate, and apply "
                    "learned guidance; warm-start new cells; tune the "
                    "optimizer's own knobs.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def add_sources(p):
        p.add_argument("--store", default=None, help="MapperStore path")
        p.add_argument("--checkpoints", nargs="*", default=None,
                       help="Tuner checkpoint files or directories")
        p.add_argument("--min-support", type=int, default=2,
                       help="distinct supporting workloads per pattern")

    p = sub.add_parser("mine", help="print the mined dataset summary "
                                    "and cross-workload patterns")
    add_sources(p)
    p.set_defaults(fn=_cmd_mine)

    p = sub.add_parser("distill", help="distill mined patterns into a "
                                       "LearnedPack JSON")
    add_sources(p)
    p.add_argument("--name", default="learned")
    p.add_argument("--min-lift", type=float, default=1.5)
    p.add_argument("--max-rules", type=int, default=8)
    p.add_argument("--out", default="learned_pack.json")
    p.set_defaults(fn=_cmd_distill)

    p = sub.add_parser("validate", help="gate a pack on held-out "
                                        "workloads (writes the verdict "
                                        "back into the pack file)")
    p.add_argument("--pack", required=True)
    p.add_argument("--workloads", nargs="+", required=True)
    p.add_argument("--strategy", default="trace")
    p.add_argument("--iters", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    p.set_defaults(fn=_cmd_validate)

    p = sub.add_parser("warm-start", help="rank neighbor cells and seed "
                                          "a new cell from their best "
                                          "artifacts")
    p.add_argument("--store", required=True)
    p.add_argument("--workload", required=True)
    p.add_argument("--k", type=int, default=3)
    p.add_argument("--tune", action="store_true",
                   help="run warm vs cold tuning and report both")
    p.add_argument("--strategy", default="trace")
    p.add_argument("--iters", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    p.set_defaults(fn=_cmd_warm_start)

    p = sub.add_parser("meta-tune", help="sweep optimizer knobs against "
                                         "iterations-to-beat-expert")
    p.add_argument("--workloads", nargs="+", required=True)
    p.add_argument("--strategy", default="opro")
    p.add_argument("--iters", type=int, default=8)
    p.add_argument("--seeds", nargs="+", type=int, default=[0])
    p.set_defaults(fn=_cmd_meta_tune)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
