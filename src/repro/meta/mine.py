"""TraceMiner: walk the system's own tuning history and structure it.

PRs 5-9 left exactly the raw material a meta-optimizer needs lying on
disk: MapperStore artifacts (winners with provenance, keyed by
(workload, mesh, profile)), Tuner checkpoints (full per-iteration
trajectories with decision assignments), and the structured
ExecutionReports riding on every checkpointed record.  The miner turns
that heap into a :class:`TraceDataset`:

* one :class:`MinedTrace` per source (a checkpoint session or a store
  artifact), normalized to (workload, mesh, profile) provenance keys;
* cross-workload aggregates over it -- ``win_patterns`` (decision
  assignments over-represented among each workload's better half of
  scored candidates) and ``fix_patterns`` (decision edits that turned a
  failing candidate into the next scoring one) -- the evidence
  :func:`repro.meta.learned.distill_pack` phrases into guidance rules.

Scores are never compared across workloads (scales differ); the
better/worse split is computed per trace and only the *counts* cross
workloads.  Everything is deterministic: mining the same store +
checkpoints yields the same dataset, patterns, and ordering.
"""

from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

#: Checkpoint versions the miner understands (mirrors repro.asi.tuner).
_CKPT_READABLE = (1, 2)


@dataclass
class MinedRecord:
    """One candidate evaluation, normalized across sources."""

    values: Dict                      # bundle -> decisions
    score: Optional[float]            # seconds; None = failed/screened
    category: str = "OK"              # ErrorCategory value (string form)
    message: str = ""                 # report message / feedback head
    primary: bool = True


@dataclass
class MinedTrace:
    """One tuning trajectory (or published winner) with provenance."""

    workload: str
    substrate: str
    mesh: str
    profile: str
    strategy: str
    source: str                       # "checkpoint:<path>" | "artifact:<id>"
    records: List[MinedRecord] = field(default_factory=list)

    def key(self) -> Tuple[str, str, str]:
        return (self.workload, self.mesh, self.profile)

    def scored(self) -> List[MinedRecord]:
        return [r for r in self.records if r.score is not None]


def _arm(value) -> str:
    """Hashable form of a decision value (mirrors the bandit's arms)."""
    return json.dumps(value, sort_keys=True, default=str)


def _signature(message: str) -> str:
    """Scale-free error signature: lowercased head with numbers struck,
    so 'peak HBM 18.2 GiB' and 'peak HBM 97.0 GiB' mine as one fault."""
    head = message.strip().splitlines()[0] if message.strip() else ""
    head = re.sub(r"\d+(\.\d+)?", "#", head.lower())
    return head[:120]


def _axes(values: Dict) -> Iterable[Tuple[str, str, str, object]]:
    """Flatten a decision dict into (bundle, key, arm, raw) axes."""
    for bundle in sorted(values):
        bvals = values[bundle]
        if not isinstance(bvals, dict):
            continue
        for key in sorted(bvals):
            yield bundle, key, _arm(bvals[key]), bvals[key]


@dataclass
class TraceDataset:
    """Mined history plus the cross-workload aggregations over it."""

    traces: List[MinedTrace] = field(default_factory=list)

    def provenance_keys(self) -> List[Tuple[str, str, str]]:
        return sorted({t.key() for t in self.traces})

    def substrates(self) -> List[str]:
        return sorted({t.substrate for t in self.traces if t.substrate})

    # -- aggregate 1: winning decision assignments ---------------------------
    def win_patterns(self, min_support: int = 2,
                     min_lift: float = 1.5) -> List[Dict]:
        """Decision assignments over-represented in each trace's better
        half of scored candidates.

        For every trace, scored records split at the median into a
        better and a worse half; per (substrate, bundle, key, value)
        assignment the dataset counts better/worse memberships across
        all traces.  Patterns with Laplace-smoothed lift
        ``(better+1)/(worse+1) >= min_lift`` supported by at least
        ``min_support`` distinct workloads survive, best lift first.
        """
        better: Dict[Tuple, int] = {}
        worse: Dict[Tuple, int] = {}
        support: Dict[Tuple, set] = {}
        raws: Dict[Tuple, object] = {}
        for trace in self.traces:
            scored = sorted(trace.scored(), key=lambda r: r.score)
            if len(scored) < 2:
                continue
            half = max(1, len(scored) // 2)
            for rank, rec in enumerate(scored):
                side = better if rank < half else worse
                for bundle, key, arm, raw in _axes(rec.values):
                    pat = (trace.substrate, bundle, key, arm)
                    side[pat] = side.get(pat, 0) + 1
                    raws.setdefault(pat, raw)
                    if rank < half:
                        support.setdefault(pat, set()).add(trace.key())
        out = []
        for pat, b in better.items():
            w = worse.get(pat, 0)
            lift = (b + 1) / (w + 1)
            wls = sorted(support.get(pat, ()))
            if lift >= min_lift and len({k[0] for k in wls}) >= min_support:
                substrate, bundle, key, _ = pat
                out.append({"substrate": substrate, "bundle": bundle,
                            "key": key, "value": raws[pat], "lift": lift,
                            "better": b, "worse": w, "support": wls})
        out.sort(key=lambda p: (-p["lift"], -p["better"], p["bundle"],
                                p["key"], _arm(p["value"])))
        return out

    # -- aggregate 2: error -> fix transitions -------------------------------
    def fix_patterns(self, min_support: int = 2) -> List[Dict]:
        """Decision edits that turned a failing primary candidate into
        the next primary candidate that scored.

        Groups by (substrate, error signature, bundle, key, new value);
        a pattern needs ``min_support`` distinct supporting workloads.
        Most-seen first.
        """
        counts: Dict[Tuple, int] = {}
        support: Dict[Tuple, set] = {}
        raws: Dict[Tuple, object] = {}
        messages: Dict[Tuple, str] = {}
        categories: Dict[Tuple, str] = {}
        for trace in self.traces:
            chain = [r for r in trace.records if r.primary]
            for i, rec in enumerate(chain):
                if rec.score is not None or rec.category == "OK":
                    continue
                fix = next((n for n in chain[i + 1:]
                            if n.score is not None), None)
                if fix is None:
                    continue
                sig = _signature(rec.message)
                before = {(b, k): a for b, k, a, _ in _axes(rec.values)}
                for bundle, key, arm, raw in _axes(fix.values):
                    if before.get((bundle, key)) in (None, arm):
                        continue
                    pat = (trace.substrate, sig, bundle, key, arm)
                    counts[pat] = counts.get(pat, 0) + 1
                    support.setdefault(pat, set()).add(trace.key())
                    raws.setdefault(pat, raw)
                    messages.setdefault(pat, rec.message)
                    categories.setdefault(pat, rec.category)
        out = []
        for pat, n in counts.items():
            wls = sorted(support[pat])
            if len({k[0] for k in wls}) < min_support:
                continue
            substrate, sig, bundle, key, _ = pat
            out.append({"substrate": substrate, "signature": sig,
                        "category": categories[pat],
                        "message": messages[pat], "bundle": bundle,
                        "key": key, "value": raws[pat], "count": n,
                        "support": wls})
        out.sort(key=lambda p: (-p["count"], p["signature"], p["bundle"],
                                p["key"], _arm(p["value"])))
        return out

    def summary(self) -> Dict:
        return {"traces": len(self.traces),
                "records": sum(len(t.records) for t in self.traces),
                "keys": [list(k) for k in self.provenance_keys()],
                "substrates": self.substrates()}


class TraceMiner:
    """Walk a MapperStore and/or Tuner checkpoints into a TraceDataset.

    ``store`` is a :class:`repro.service.MapperStore` (or its path);
    ``checkpoints`` is any mix of checkpoint files and directories
    (directories are scanned for ``*.json`` files, non-checkpoint JSON
    is skipped).  Workload substrate/mesh/profile resolve through the
    ASI registry when the workload is registered, through the artifact
    row otherwise.
    """

    def __init__(self, store=None,
                 checkpoints: Sequence[str] = ()):
        self.store = store
        self.checkpoints = list(checkpoints)

    # -- source: MapperStore -------------------------------------------------
    def _mine_store(self, out: List[MinedTrace]) -> None:
        from ..service import MapperStore
        store = self.store
        if store is None:
            return
        if not isinstance(store, MapperStore):
            store = MapperStore(str(store))
        for art in store.list():
            prov = art.provenance or {}
            rec = MinedRecord(values=prov.get("decisions") or {},
                              score=art.score, category="OK",
                              message=f"published winner "
                                      f"({prov.get('source', 'unknown')})")
            out.append(MinedTrace(
                workload=art.workload, substrate=art.substrate,
                mesh=art.mesh, profile=art.profile,
                strategy=str(prov.get("strategy", "")),
                source=f"artifact:{art.id}", records=[rec]))

    # -- source: Tuner checkpoints -------------------------------------------
    def _checkpoint_paths(self) -> List[str]:
        paths = []
        for entry in self.checkpoints:
            if os.path.isdir(entry):
                paths.extend(sorted(
                    os.path.join(entry, f) for f in os.listdir(entry)
                    if f.endswith(".json")))
            else:
                paths.append(entry)
        return paths

    def _mine_checkpoint(self, path: str, out: List[MinedTrace]) -> None:
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            return
        if not isinstance(payload, dict) \
                or payload.get("version") not in _CKPT_READABLE \
                or "session" not in payload:
            return                      # not a Tuner checkpoint
        wname = str(payload.get("workload", ""))
        substrate, mesh, profile = self._resolve(wname)
        trace = MinedTrace(workload=wname, substrate=substrate,
                           mesh=mesh, profile=profile,
                           strategy=str(payload.get("strategy", "")),
                           source=f"checkpoint:{path}")
        for r in payload["session"].get("records", ()):
            rep = r.get("report") or {}
            category = str(rep.get("category", "OK" if r.get("score")
                                   is not None else "EXECUTION"))
            message = str(rep.get("message", "")) or \
                str(r.get("feedback", "")).strip().split("\n")[0]
            trace.records.append(MinedRecord(
                values=r.get("values") or {}, score=r.get("score"),
                category=category, message=message,
                primary=bool(r.get("primary", True))))
        out.append(trace)

    @staticmethod
    def _resolve(wname: str) -> Tuple[str, str, str]:
        """(substrate, mesh, profile) of a workload name, via the
        registry when registered; blanks otherwise (still minable)."""
        try:
            from ..asi import registry
            from ..service import workload_mesh, workload_profile
            wl = registry.get(wname)
            return (getattr(wl, "substrate", ""), workload_mesh(wl),
                    workload_profile(wl))
        except Exception:
            return ("", "", "healthy")

    def mine(self) -> TraceDataset:
        traces: List[MinedTrace] = []
        self._mine_store(traces)
        for path in self._checkpoint_paths():
            self._mine_checkpoint(path, traces)
        return TraceDataset(traces=traces)


def mine_traces(store=None, checkpoints: Sequence[str] = ()) -> TraceDataset:
    """Convenience wrapper: ``TraceMiner(store, checkpoints).mine()``."""
    return TraceMiner(store=store, checkpoints=checkpoints).mine()
