"""WarmStart: seed a new tuning cell from its nearest solved neighbors.

When a (workload, mesh geometry, device profile) cell is tuned for the
first time, the MapperStore usually already holds winners for *related*
cells -- the same algorithm on another mesh, a sibling of the same
family (the matmul variants share one decision space), or the same
workload under a degraded profile.  :class:`NeighborIndex` ranks those
cells by a weighted similarity over

* substrate (0.4) -- guidance rules, cost models, and decision
  vocabularies are substrate-scoped, so cross-substrate transfer is
  near-worthless;
* decision-space overlap (0.3) -- Jaccard over (bundle, key) axes;
* mesh geometry (0.2) -- device-count ratio and rank match of the
  ``RxC:axes`` geometry keys;
* profile match (0.1).

:func:`adapt_decisions` then translates a neighbor's winning decision
assignment into the target's space (exact-axis adoption plus
majority-value fill for unmatched keys), and
:func:`warm_start_candidates` packages the top-k as seed candidates for
``Tuner(seed_candidates=...)``.  Neighbor scores are deliberately
dropped (``score=None``): a rival workload's seconds are not on this
workload's scale and must never win a best-score comparison here.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

#: Similarity component weights (sum to 1.0).
WEIGHTS = {"substrate": 0.4, "space": 0.3, "mesh": 0.2, "profile": 0.1}


def _parse_mesh(key: str) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """``"2x4:data,model"`` -> ``((2, 4), ("data", "model"))``."""
    geom, _, axes = key.partition(":")
    shape = []
    for part in geom.split("x"):
        try:
            shape.append(int(part))
        except ValueError:
            return ((), ())
    return (tuple(shape),
            tuple(a for a in axes.split(",") if a) if axes else ())


def mesh_similarity(a: str, b: str) -> float:
    """Geometry similarity of two mesh keys in [0, 1]."""
    if a == b:
        return 1.0
    shape_a, _ = _parse_mesh(a)
    shape_b, _ = _parse_mesh(b)
    if not shape_a or not shape_b:
        return 0.0
    count_a, count_b = 1, 1
    for s in shape_a:
        count_a *= s
    for s in shape_b:
        count_b *= s
    ratio = min(count_a, count_b) / max(count_a, count_b)
    rank = 1.0 if len(shape_a) == len(shape_b) else 0.5
    return 0.5 * ratio + 0.5 * rank


def _space_axes(workload) -> set:
    """The (bundle, key) axis set of a workload's decision space."""
    try:
        return {(bundle, key) for bundle, keys in workload.bundles().items()
                for key in keys}
    except Exception:
        return set()


def _axes_of_decisions(decisions: Dict) -> set:
    return {(bundle, key) for bundle, keys in (decisions or {}).items()
            if isinstance(keys, dict) for key in keys}


def space_similarity(target_axes: set, source_axes: set) -> float:
    """Jaccard overlap of two (bundle, key) axis sets."""
    if not target_axes or not source_axes:
        return 0.0
    inter = len(target_axes & source_axes)
    union = len(target_axes | source_axes)
    return inter / union


@dataclass
class Neighbor:
    """A ranked neighbor cell: its best artifact plus the score parts."""

    artifact: object                  # MapperArtifact
    similarity: float
    parts: Dict[str, float] = field(default_factory=dict)

    def describe(self) -> Dict:
        return {"workload": self.artifact.workload,
                "mesh": self.artifact.mesh,
                "profile": self.artifact.profile,
                "artifact": self.artifact.id,
                "similarity": round(self.similarity, 4),
                "parts": {k: round(v, 4) for k, v in self.parts.items()}}


class NeighborIndex:
    """Rank MapperStore cells by similarity to a target workload cell.

    Decision-space axes resolve through the ASI registry when the
    neighbor workload is registered there; otherwise they fall back to
    the axes visible in the artifact's provenance decisions (mined
    stores from other hosts stay usable).
    """

    def __init__(self, store, registry=None):
        from ..asi import registry as default_registry
        from ..service import MapperStore
        if not isinstance(store, MapperStore):
            store = MapperStore(str(store))
        self.store = store
        self.registry = registry or default_registry

    def _source_axes(self, artifact) -> set:
        try:
            return _space_axes(self.registry.get(artifact.workload))
        except Exception:
            prov = artifact.provenance or {}
            return _axes_of_decisions(prov.get("decisions"))

    def neighbors(self, workload, k: int = 3,
                  profile: Optional[str] = None) -> List[Neighbor]:
        """Top-``k`` neighbor cells of ``workload``, most similar first.

        The target cell itself (same workload, mesh, profile) is
        excluded -- resuming your own winner is the store's ``best()``,
        not a warm start.  Ties break on (workload, mesh, profile) so
        the ranking is deterministic.
        """
        from ..service import workload_mesh, workload_profile
        target_sub = getattr(workload, "substrate", "")
        target_mesh = workload_mesh(workload)
        target_profile = profile or workload_profile(workload)
        target_axes = _space_axes(workload)
        target_key = (getattr(workload, "name", ""), target_mesh,
                      target_profile)
        ranked: List[Neighbor] = []
        for key in self.store.keys():
            if key == target_key:
                continue
            art = self.store.best(key[0], mesh=key[1], profile=key[2])
            if art is None:
                continue
            parts = {
                "substrate": 1.0 if art.substrate == target_sub else 0.0,
                "space": space_similarity(target_axes,
                                          self._source_axes(art)),
                "mesh": mesh_similarity(target_mesh, art.mesh),
                "profile": 1.0 if art.profile == target_profile else 0.0,
            }
            sim = sum(WEIGHTS[name] * val for name, val in parts.items())
            ranked.append(Neighbor(artifact=art, similarity=sim,
                                   parts=parts))
        ranked.sort(key=lambda n: (-n.similarity, n.artifact.workload,
                                   n.artifact.mesh, n.artifact.profile))
        return ranked[:k]


def adapt_decisions(source: Dict, workload) -> Optional[Dict]:
    """Translate a neighbor's decision assignment into ``workload``'s
    decision space.

    Exact (bundle, key) axes adopt the source value when it is allowed
    on the target axis.  Target keys with no exact match fall back to
    the majority value the source assigned under the *same bundle* --
    apps share value vocabularies (layouts, index functions) even when
    per-task keys are named differently -- provided that value is
    allowed; everything else keeps the target default.  Returns None
    when nothing transferred (the caller should not seed a candidate
    that is just the default restated).
    """
    try:
        defaults = workload.default_decisions()
        spaces = workload.bundles()
    except Exception:
        return None
    out = json.loads(json.dumps(defaults))
    transferred = 0
    for bundle, keys in out.items():
        if not isinstance(keys, dict):
            continue
        src_bundle = (source or {}).get(bundle)
        if not isinstance(src_bundle, dict):
            continue
        allowed = spaces.get(bundle, {})
        # majority value of the source bundle, deterministic tie-break
        tally: Dict[str, int] = {}
        raw_by_arm: Dict[str, object] = {}
        for val in src_bundle.values():
            arm = json.dumps(val, sort_keys=True, default=str)
            tally[arm] = tally.get(arm, 0) + 1
            raw_by_arm.setdefault(arm, val)
        majority = None
        if tally:
            best_arm = min(tally, key=lambda a: (-tally[a], a))
            majority = raw_by_arm[best_arm]
        for key in keys:
            options = allowed.get(key, ())
            if key in src_bundle and src_bundle[key] in options:
                if out[bundle][key] != src_bundle[key]:
                    transferred += 1
                out[bundle][key] = src_bundle[key]
            elif majority is not None and majority in options:
                if out[bundle][key] != majority:
                    transferred += 1
                out[bundle][key] = majority
    return out if transferred else None


def warm_start_candidates(workload, store, k: int = 3,
                          profile: Optional[str] = None,
                          registry=None) -> List[Dict]:
    """Seed candidates for ``Tuner(seed_candidates=...)`` mined from the
    nearest neighbors' best artifacts, nearest first.

    Each candidate is ``{"decisions": ..., "score": None, "from": ...}``
    -- score stays None so a foreign scale never beats live
    measurements.  Deduplicates identical adapted assignments.
    """
    index = NeighborIndex(store, registry=registry)
    out: List[Dict] = []
    seen = set()
    for nb in index.neighbors(workload, k=k, profile=profile):
        prov = nb.artifact.provenance or {}
        decisions = adapt_decisions(prov.get("decisions"), workload)
        if decisions is None:
            continue
        arm = json.dumps(decisions, sort_keys=True, default=str)
        if arm in seen:
            continue
        seen.add(arm)
        out.append({"decisions": decisions, "score": None,
                    "from": nb.describe()})
    return out
