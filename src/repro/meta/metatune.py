"""MetaTuner: tune the optimizer's own knobs against a learned reward.

The inner optimizer (OPRO & friends) got three knobs in this PR --
prompt ``template`` (:data:`repro.core.agent.optimizers.OPRO_TEMPLATES`),
exploration ``temperature``, ``history_k`` -- plus the Tuner's ``batch``.
The MetaTuner sweeps :class:`MetaConfig` grid points over those knobs,
runs the inner tuning loop per (workload, seed) cell, and scores each
configuration by the paper's headline currency:
**iterations-to-beat-expert**, with ``experiments.expert_score`` as the
bar.  A configuration that never reaches the bar on a cell pays
``iterations + 1`` for it, so "never" is strictly worse than
"on the last iteration" but doesn't blow up the mean.

Everything is a seeded inner ``repro.asi.tune`` run, so the sweep is
deterministic and the winning config is reproducible evidence.  The
winner exports as an :class:`~repro.experiments.OptimizerSpec` (knobs
ride in ``spec.params``), so the experiments harness can run a
meta-tuned arm next to the defaults.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


def iterations_to_beat(trajectory: Sequence[Optional[float]],
                       bar: Optional[float]) -> Optional[int]:
    """First 1-based iteration whose best-so-far matches or beats
    ``bar``; None when the run never gets there (or there is no bar).

    Accepts trajectories in either convention: JSON-null (None) or
    ``inf`` for "no valid candidate yet".
    """
    if bar is None:
        return None
    for i, t in enumerate(trajectory):
        if t is not None and t != float("inf") and t <= bar:
            return i + 1
    return None


@dataclass(frozen=True)
class MetaConfig:
    """One grid point of optimizer hyper-parameters."""

    template: str = "classic"
    temperature: float = 0.0
    history_k: int = 5
    batch: int = 1

    def search_params(self, strategy: str) -> Dict:
        """The Search-constructor kwargs this config carries, restricted
        to what ``strategy`` accepts (template/history_k are OPRO-only;
        temperature is universal)."""
        params: Dict = {}
        if self.temperature:
            params["temperature"] = self.temperature
        if strategy == "opro":
            if self.template != "classic":
                params["template"] = self.template
            if self.history_k != 5:
                params["history_k"] = self.history_k
        return params

    def label(self) -> str:
        return (f"{self.template}/T{self.temperature:g}"
                f"/k{self.history_k}/b{self.batch}")

    def spec(self, strategy: str = "opro",
             feedback_level: str = "full"):
        """Export as an experiments OptimizerSpec (params tuple)."""
        from ..experiments import OptimizerSpec
        params = tuple(sorted(self.search_params(strategy).items()))
        return OptimizerSpec(name=f"meta[{self.label()}]",
                             strategy=strategy,
                             feedback_level=feedback_level,
                             agentic=True, params=params)


#: The default sweep: the stock configuration first (stable argmin keeps
#: it on reward ties -- never churn knobs without a measured win), then
#: the template/temperature/history alternatives.
def default_grid(strategy: str = "opro") -> List[MetaConfig]:
    configs = [MetaConfig()]
    templates = (("classic", "ascending", "terse")
                 if strategy == "opro" else ("classic",))
    ks = ((5, 3) if strategy == "opro" else (5,))
    for template, temp, k in itertools.product(
            templates, (0.0, 0.25), ks):
        cfg = MetaConfig(template=template, temperature=temp, history_k=k)
        if cfg not in configs:
            configs.append(cfg)
    return configs


@dataclass
class MetaResult:
    """Sweep outcome: the winning config plus the full reward table."""

    best: MetaConfig
    reward: float                     # mean iterations-to-beat (lower wins)
    table: List[Dict] = field(default_factory=list)
    strategy: str = "opro"

    def improved(self) -> bool:
        """True when a non-default config strictly beat the default."""
        default = next((r for r in self.table
                        if r["config"] == MetaConfig().label()), None)
        return (default is not None
                and self.best != MetaConfig()
                and self.reward < default["reward"])

    def to_dict(self) -> Dict:
        return {"strategy": self.strategy,
                "best": self.best.label(),
                "best_params": {"template": self.best.template,
                                "temperature": self.best.temperature,
                                "history_k": self.best.history_k,
                                "batch": self.best.batch},
                "reward": self.reward,
                "improved": self.improved(),
                "table": self.table}


class MetaTuner:
    """Sweep MetaConfigs; reward = mean iterations-to-beat-expert.

    ``workloads`` should ship expert mappers (cells without a bar are
    skipped and reported); ``configs`` defaults to :func:`default_grid`.
    The inner loop is plain ``repro.asi.tune`` -- same front door as the
    CLI and the experiments harness.
    """

    def __init__(self, workloads: Sequence[str], strategy: str = "opro",
                 iterations: int = 8, seeds: Sequence[int] = (0,),
                 configs: Optional[Sequence[MetaConfig]] = None):
        self.workloads = list(workloads)
        self.strategy = strategy
        self.iterations = iterations
        self.seeds = list(seeds)
        self.configs = list(configs) if configs is not None \
            else default_grid(strategy)

    def _bars(self) -> Dict[str, Optional[float]]:
        from ..experiments import expert_score
        return {w: expert_score(w) for w in self.workloads}

    def _reward(self, config: MetaConfig,
                bars: Dict[str, Optional[float]]) -> Tuple[float, Dict]:
        from ..asi import tune
        cells: Dict[str, Dict] = {}
        total, n = 0.0, 0
        for wname in self.workloads:
            bar = bars[wname]
            if bar is None:
                cells[wname] = {"skipped": "no expert bar"}
                continue
            per_seed = {}
            for seed in self.seeds:
                res = tune(wname, strategy=self.strategy,
                           iterations=self.iterations, seed=seed,
                           batch=config.batch,
                           search_params=config.search_params(
                               self.strategy) or None)
                iters = iterations_to_beat(res.trajectory, bar)
                per_seed[str(seed)] = iters
                total += iters if iters is not None \
                    else self.iterations + 1
                n += 1
            cells[wname] = {"bar": bar, "iterations_to_beat": per_seed}
        reward = total / n if n else float("inf")
        return reward, cells

    def run(self) -> MetaResult:
        bars = self._bars()
        table: List[Dict] = []
        best_cfg, best_reward = None, None
        for config in self.configs:
            reward, cells = self._reward(config, bars)
            table.append({"config": config.label(), "reward": reward,
                          "cells": cells})
            if best_reward is None or reward < best_reward:
                best_cfg, best_reward = config, reward
        return MetaResult(best=best_cfg or MetaConfig(),
                          reward=best_reward if best_reward is not None
                          else float("inf"),
                          table=table, strategy=self.strategy)


def meta_tune(workloads: Sequence[str], strategy: str = "opro",
              iterations: int = 8, seeds: Sequence[int] = (0,),
              configs: Optional[Sequence[MetaConfig]] = None) -> MetaResult:
    """Convenience wrapper: ``MetaTuner(...).run()``."""
    return MetaTuner(workloads, strategy=strategy, iterations=iterations,
                     seeds=seeds, configs=configs).run()
