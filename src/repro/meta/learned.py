"""LearnedPack: distilled, provenance-carrying, validated guidance rules.

The second half of the TraceMiner pipeline (docs/meta.md):

* :func:`distill_pack` phrases the dataset's cross-workload evidence
  (win patterns, error->fix transitions) into :class:`LearnedRule`
  objects.  Each rule keeps its provenance -- the (workload, mesh,
  profile) traces supporting it -- and compiles into a plain AutoGuide
  :class:`~repro.core.agent.autoguide.Rule`, so a learned pack composes
  through the existing ``EXTRA_PACKS`` / ``get_pack`` mechanism exactly
  like the hand-written ``ft`` add-on: ``get_pack("app+learned")``.
  An optional LLM backend (the same :class:`LLMClient` protocol the
  optimizers use) may rephrase the explain/suggest channels; the default
  is the deterministic template distiller, mirroring how HeuristicLLM
  stands in for a live model everywhere else.
* :func:`validate_pack` is the activation gate: a pack ships only if
  composing it into the diagnostics does not regress
  iterations-to-beat-expert on any held-out workload, measured with the
  deterministic record/replay harness.
* :func:`register_pack` activates a *validated* pack (refusing
  unvalidated ones unless forced), and :func:`with_pack` returns a
  workload view whose evaluator diagnoses through the composed pack.

Packs serialize to JSON (rules are stored declaratively -- predicate
spec, not code) and round-trip bit-for-bit.
"""

from __future__ import annotations

import copy
import json
import random
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .mine import TraceDataset, _signature

_MISSING = object()
#: Default cap on rules per pack: guidance, not an avalanche.
MAX_RULES = 8


@dataclass
class LearnedRule:
    """One distilled rule: declarative predicate + channels + provenance.

    Unlike the hand-written packs, the predicate is *data* (kind,
    substrate, category, signature), so the rule survives a JSON round
    trip; :meth:`to_rule` compiles it to a live AutoGuide ``Rule``.
    """

    name: str
    kind: str                          # "win" | "fix"
    substrate: str
    explain: str
    suggest: str
    bundle: str
    key: str
    value: object
    category: str = "OK"               # ErrorCategory value
    signature: str = ""                # error signature ("fix" rules)
    message: str = ""                  # example message the rule fires on
    #: The (workload, mesh, profile) traces that support this rule.
    support: List[List[str]] = field(default_factory=list)
    stats: Dict = field(default_factory=dict)

    def to_rule(self):
        from ..core.agent.autoguide.report import (ErrorCategory,
                                                   ExecutionReport)
        from ..core.agent.autoguide.rules import Rule
        substrate = self.substrate

        if self.kind == "fix":
            category = ErrorCategory(self.category)
            signature = self.signature

            def when(r, _sig=signature, _sub=substrate):
                if _sub and r.substrate not in ("", _sub):
                    return False
                return _signature(r.message) == _sig

            message = self.message
            score = None
        else:
            category = ErrorCategory.OK

            def when(r, _sub=substrate):
                if _sub and r.substrate not in ("", _sub):
                    return False
                return r.score is not None

            message = (self.message
                       or "Performance Metric: execution time is 1.0s.")
            score = 1.0

        def example(_cat=category, _msg=message, _sub=substrate,
                    _score=score):
            return ExecutionReport(category=_cat, message=_msg,
                                   substrate=_sub, score=_score)

        return Rule(name=self.name, category=category, when=when,
                    explain=self.explain, suggest=self.suggest,
                    example=example)

    def to_dict(self) -> Dict:
        return {"name": self.name, "kind": self.kind,
                "substrate": self.substrate, "explain": self.explain,
                "suggest": self.suggest, "bundle": self.bundle,
                "key": self.key, "value": self.value,
                "category": self.category, "signature": self.signature,
                "message": self.message, "support": self.support,
                "stats": self.stats}

    @classmethod
    def from_dict(cls, d: Dict) -> "LearnedRule":
        return cls(**d)


@dataclass
class LearnedPack:
    """A named set of learned rules, with source + validation metadata."""

    name: str
    rules: List[LearnedRule] = field(default_factory=list)
    created: float = 0.0
    source: Dict = field(default_factory=dict)     # miner summary
    #: None until :func:`validate_pack` ran; then the verdict payload
    #: (``{"passed": bool, "workloads": {...}, ...}``).
    validation: Optional[Dict] = None

    def rules_tuple(self) -> Tuple:
        return tuple(r.to_rule() for r in self.rules)

    def to_dict(self) -> Dict:
        return {"name": self.name, "created": self.created,
                "rules": [r.to_dict() for r in self.rules],
                "source": self.source, "validation": self.validation}

    @classmethod
    def from_dict(cls, d: Dict) -> "LearnedPack":
        return cls(name=d["name"], created=d.get("created", 0.0),
                   rules=[LearnedRule.from_dict(r) for r in d["rules"]],
                   source=d.get("source", {}),
                   validation=d.get("validation"))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, allow_nan=False)

    @classmethod
    def load(cls, path: str) -> "LearnedPack":
        with open(path) as f:
            return cls.from_dict(json.load(f))


# ---------------------------------------------------------------------------
# Distillation
# ---------------------------------------------------------------------------
def _phrase(llm, prompt: str, explain: str, suggest: str,
            rng: random.Random) -> Tuple[str, str]:
    """Route a default phrasing through the LLM backend (None = keep)."""
    if llm is None:
        return explain, suggest
    out = llm.propose(prompt, {"rule": {"explain": explain,
                                        "suggest": suggest}}, rng)
    rule = out.get("rule", {}) if isinstance(out, dict) else {}
    return (str(rule.get("explain", explain)),
            str(rule.get("suggest", suggest)))


def _fmt_value(value) -> str:
    return value if isinstance(value, str) else json.dumps(value)


def distill_pack(dataset: TraceDataset, name: str = "learned",
                 llm=None, min_support: int = 2, min_lift: float = 1.5,
                 max_rules: int = MAX_RULES) -> LearnedPack:
    """Distill a mined dataset into an (unvalidated) LearnedPack.

    Fix patterns (an error signature plus the decision edit that
    recovered from it) come first -- they are the most actionable
    guidance -- then win patterns, until ``max_rules``.  ``llm`` is an
    optional :class:`LLMClient`-protocol backend given a chance to
    rephrase each rule's explain/suggest channels (a ScriptedLLM makes
    that deterministic in tests; None keeps the template phrasing).
    Deterministic for a fixed dataset + backend.
    """
    rng = random.Random(0)
    rules: List[LearnedRule] = []
    for pat in dataset.fix_patterns(min_support=min_support):
        if len(rules) >= max_rules:
            break
        n = len(pat["support"])
        val = _fmt_value(pat["value"])
        explain = (f"Across {n} tuned cells this fault was followed by a "
                   f"recovery that changed {pat['key']} in "
                   f"{pat['bundle']}.")
        suggest = (f"Set {pat['key']} to {val} in {pat['bundle']} -- the "
                   f"fix mined from {n} prior traces.")
        prompt = (f"Phrase a diagnostic rule for substrate "
                  f"{pat['substrate']!r}: error '{pat['signature']}' was "
                  f"fixed by {pat['bundle']}.{pat['key']}={val} "
                  f"{pat['count']} times.")
        explain, suggest = _phrase(llm, prompt, explain, suggest, rng)
        rules.append(LearnedRule(
            name=f"{name}-fix-{len(rules)}", kind="fix",
            substrate=pat["substrate"], explain=explain, suggest=suggest,
            bundle=pat["bundle"], key=pat["key"], value=pat["value"],
            category=pat["category"], signature=pat["signature"],
            message=pat["message"], support=[list(k)
                                             for k in pat["support"]],
            stats={"count": pat["count"]}))
    for pat in dataset.win_patterns(min_support=min_support,
                                    min_lift=min_lift):
        if len(rules) >= max_rules:
            break
        n = len({k[0] for k in pat["support"]})
        val = _fmt_value(pat["value"])
        explain = (f"Mappers setting {pat['key']} to {val} in "
                   f"{pat['bundle']} ranked in the better half on "
                   f"{n} workloads (lift {pat['lift']:.1f}x).")
        suggest = (f"Prefer {pat['key']}={val} in {pat['bundle']} unless "
                   f"the cost breakdown argues otherwise.")
        prompt = (f"Phrase a guidance rule for substrate "
                  f"{pat['substrate']!r}: {pat['bundle']}.{pat['key']}"
                  f"={val} wins (lift {pat['lift']:.2f}).")
        explain, suggest = _phrase(llm, prompt, explain, suggest, rng)
        rules.append(LearnedRule(
            name=f"{name}-win-{len(rules)}", kind="win",
            substrate=pat["substrate"], explain=explain, suggest=suggest,
            bundle=pat["bundle"], key=pat["key"], value=pat["value"],
            support=[list(k) for k in pat["support"]],
            stats={"lift": pat["lift"], "better": pat["better"],
                   "worse": pat["worse"]}))
    return LearnedPack(name=name, rules=rules, created=time.time(),
                       source=dataset.summary())


# ---------------------------------------------------------------------------
# Activation (EXTRA_PACKS composition) + workload views
# ---------------------------------------------------------------------------
def register_pack(pack: LearnedPack, force: bool = False) -> str:
    """Activate ``pack`` as an EXTRA_PACKS add-on (``"app+<name>"``).

    Unvalidated or failed packs are refused unless ``force=True`` --
    the ISSUE's shipping gate: a learned rule only reaches live
    diagnostics after the replay-harness validation passed.
    """
    from ..core.agent.autoguide.rules import EXTRA_PACKS, RULE_PACKS
    if not force and not (pack.validation or {}).get("passed"):
        raise ValueError(
            f"learned pack {pack.name!r} is not validated; run "
            "validate_pack() first (or force=True to bypass the gate)")
    if "+" in pack.name or not pack.name:
        raise ValueError(f"invalid pack name {pack.name!r}")
    if pack.name in RULE_PACKS or pack.name == "ft":
        raise ValueError(f"pack name {pack.name!r} shadows a built-in")
    EXTRA_PACKS[pack.name] = pack.rules_tuple()
    return pack.name


def with_pack(workload, pack: LearnedPack):
    """A view of ``workload`` whose diagnostics compose ``pack``.

    Returns a shallow copy with ``rule_pack = "<own>+<pack name>"`` and
    a freshly built evaluator bound to the composed pack; the original
    (possibly registry-cached) instance is untouched.  The pack must
    already be registered (see :func:`register_pack`).
    """
    from ..core.agent.autoguide.rules import get_pack
    composed = f"{workload.rule_pack}+{pack.name}"
    get_pack(composed)                   # fail fast on unregistered packs
    wl = copy.copy(workload)
    wl.rule_pack = composed
    wl._evaluator = None
    ev = wl.evaluator()
    if hasattr(ev, "pack"):              # CallableEvaluator (app/matmul)
        ev.pack = composed
    else:                                # tiered engine (lm)
        eng = getattr(ev, "engine", None)
        if eng is not None and hasattr(eng, "rule_pack"):
            eng.rule_pack = composed
    return wl


# ---------------------------------------------------------------------------
# Validation: the activation gate
# ---------------------------------------------------------------------------
def validate_pack(pack: LearnedPack, workloads: Sequence[str],
                  strategy: str = "trace", iterations: int = 8,
                  seed: int = 0, check_replay: bool = True) -> Dict:
    """Gate ``pack`` on held-out workloads; sets ``pack.validation``.

    For every workload the baseline arm tunes with the substrate's own
    diagnostics and the candidate arm with ``+<pack>`` composed in, same
    strategy/seed/iterations; the metric is iterations-to-beat-expert
    (``experiments.expert_score`` is the bar).  The pack passes only if
    no workload regresses.  ``check_replay`` additionally records the
    first candidate run's LLM exchanges and replays them bit-for-bit
    (the deterministic record/replay harness), so the verdict is
    reproducible evidence, not a flaky measurement.
    """
    from ..asi import registry, tune
    from ..core.agent.autoguide.rules import EXTRA_PACKS
    from ..core.agent.llm import RecordingLLM, ReplayLLM, ReplayMismatch
    from .metatune import iterations_to_beat

    prev = EXTRA_PACKS.get(pack.name, _MISSING)
    EXTRA_PACKS[pack.name] = pack.rules_tuple()
    verdict: Dict = {"workloads": {}, "strategy": strategy,
                     "iterations": iterations, "seed": seed}
    try:
        regressions = []
        replay_identical = None
        for i, wname in enumerate(workloads):
            from ..experiments import expert_score
            wl = registry.get(wname)
            bar = expert_score(wname)
            base_res = tune(wl, strategy=strategy, iterations=iterations,
                            seed=seed)
            view = with_pack(wl, pack)
            llm = None
            recorder = None
            if check_replay and i == 0:
                llm = recorder = RecordingLLM(view.llm())
            cand_res = tune(view, strategy=strategy,
                            iterations=iterations, seed=seed, llm=llm)
            if recorder is not None:
                try:
                    replay = tune(with_pack(wl, pack), strategy=strategy,
                                  iterations=iterations, seed=seed,
                                  llm=ReplayLLM(recorder.calls,
                                                strict=True))
                    replay_identical = (replay.trajectory
                                        == cand_res.trajectory)
                except ReplayMismatch:
                    replay_identical = False
            base_iters = iterations_to_beat(base_res.trajectory, bar)
            cand_iters = iterations_to_beat(cand_res.trajectory, bar)
            regressed = (base_iters is not None
                         and (cand_iters is None
                              or cand_iters > base_iters))
            if regressed:
                regressions.append(wname)
            verdict["workloads"][wname] = {
                "expert_score": bar,
                "baseline_iterations_to_beat": base_iters,
                "learned_iterations_to_beat": cand_iters,
                "regressed": regressed}
        verdict["replay_identical"] = replay_identical
        verdict["regressions"] = regressions
        verdict["passed"] = (not regressions
                             and replay_identical is not False)
    finally:
        if prev is _MISSING:
            EXTRA_PACKS.pop(pack.name, None)
        else:
            EXTRA_PACKS[pack.name] = prev
    pack.validation = verdict
    return verdict
