"""repro.meta -- the meta-optimization layer: mine the system's own
tuning history to improve the optimizer itself.

After enough tuning runs, the MapperStore and the checkpoint piles are
themselves a dataset.  This package closes the loop over it, three ways
(docs/meta.md has the walkthrough):

* **TraceMiner** (:mod:`repro.meta.mine`) walks store artifacts and
  Tuner checkpoints into a :class:`TraceDataset` of normalized traces
  with (workload, mesh, profile) provenance, and aggregates
  cross-workload evidence: winning decision assignments and
  error->fix transitions.
* **LearnedPack** (:mod:`repro.meta.learned`) distills that evidence
  into guidance rules that compose into AutoGuide through the existing
  ``EXTRA_PACKS`` mechanism (``get_pack("app+learned")``) -- gated by
  :func:`validate_pack`: a pack ships only if it does not regress
  iterations-to-beat-expert on held-out workloads under the
  deterministic record/replay harness.
* **WarmStart** (:mod:`repro.meta.warmstart`) ranks solved neighbor
  cells by substrate/decision-space/mesh-geometry similarity and seeds
  a new cell's opening candidates from their best artifacts via
  ``Tuner(seed_candidates=...)``.
* **MetaTuner** (:mod:`repro.meta.metatune`) sweeps the optimizer's own
  knobs (OPRO prompt template, exploration temperature, history window,
  batch) against the iterations-to-beat-expert reward.

CLI::

    python -m repro.meta mine --store store.db --checkpoints runs/
    python -m repro.meta distill --store store.db --out pack.json
    python -m repro.meta validate --pack pack.json --workloads circuit
    python -m repro.meta warm-start --store store.db --workload cannon
"""

from .learned import (LearnedPack, LearnedRule, distill_pack,
                      register_pack, validate_pack, with_pack)
from .metatune import (MetaConfig, MetaResult, MetaTuner, default_grid,
                       iterations_to_beat, meta_tune)
from .mine import (MinedRecord, MinedTrace, TraceDataset, TraceMiner,
                   mine_traces)
from .warmstart import (Neighbor, NeighborIndex, adapt_decisions,
                        mesh_similarity, warm_start_candidates)

__all__ = [
    "LearnedPack", "LearnedRule", "MetaConfig", "MetaResult", "MetaTuner",
    "MinedRecord", "MinedTrace", "Neighbor", "NeighborIndex",
    "TraceDataset", "TraceMiner", "adapt_decisions", "default_grid",
    "distill_pack", "iterations_to_beat", "mesh_similarity", "meta_tune",
    "mine_traces", "register_pack", "validate_pack",
    "warm_start_candidates", "with_pack",
]
