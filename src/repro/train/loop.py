"""Training loop: mapped train_step + data + checkpointing + watchdog.

Runs at any scale the mesh provides -- host devices for tests/examples,
the production mesh under the dry-run.  Fault tolerance: async
checkpoints every ``ckpt_every`` steps, auto-resume from the latest
commit, straggler watchdog, deterministic host-sharded data.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint.checkpoint import AsyncCheckpointer, latest_step, restore
from ..core.dsl.compiler import compile_mapper
from ..core.mapping.lm_bridge import rules_from_plan
from ..data.pipeline import make_pipeline
from ..ft.straggler import StepWatchdog
from ..launch.mesh import machine_factory_for_mesh
from ..launch.steps import batch_shardings, make_train_step, replicated
from ..models.registry import Model
from ..parallel.sharding import param_shardings
from ..train.optim import AdamWConfig, adamw_init


@dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 256
    seed: int = 0
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    opt: AdamWConfig = field(default_factory=AdamWConfig)


def train(model: Model, mesh, mapper_src: str, cfg: TrainConfig,
          log: Callable[[str], None] = print) -> Dict:
    plan = compile_mapper(mapper_src, machine_factory_for_mesh(mesh))
    rules = rules_from_plan(plan, mesh, "train")
    abstract = model.abstract_params()
    axes = model.param_axes()
    p_sh = param_shardings(axes, rules, abstract)

    train_step = make_train_step(model, rules, cfg.opt)
    pipe = make_pipeline(model.cfg.vocab_size, cfg.batch, cfg.seq_len,
                         cfg.seed)
    sample = {"tokens": pipe.batch_at(0)["tokens"]}
    b_sh = batch_shardings(rules, jax.eval_shape(lambda: sample))

    opt_abstract = jax.eval_shape(adamw_init, abstract)
    m_sh = param_shardings(axes, rules, opt_abstract.m)
    from ..train.optim import AdamWState
    opt_sh = AdamWState(step=replicated(rules), m=m_sh, v=m_sh)

    jitted = jax.jit(train_step,
                     in_shardings=(p_sh, opt_sh, b_sh),
                     out_shardings=(p_sh, opt_sh, None),
                     donate_argnums=(0, 1))

    start_step = 0
    with mesh:
        params = None
        if cfg.ckpt_dir and latest_step(cfg.ckpt_dir) is not None:
            state_like = {"params": abstract, "opt": opt_abstract}
            state_sh = {"params": p_sh,
                        "opt": AdamWState(step=None, m=m_sh, v=m_sh)}
            restored, start_step, _ = restore(cfg.ckpt_dir, state_like,
                                              shardings=state_sh)
            params, opt_state = restored["params"], restored["opt"]
            log(f"resumed from step {start_step}")
        if params is None:
            params = model.init(jax.random.PRNGKey(cfg.seed))
            params = jax.device_put(params, p_sh)
            opt_state = adamw_init(params)

        ckpt = AsyncCheckpointer(cfg.ckpt_dir) if cfg.ckpt_dir else None
        watchdog = StepWatchdog()
        losses: List[float] = []
        t_start = time.perf_counter()
        for step in range(start_step, cfg.steps):
            batch = jax.tree.map(jax.numpy.asarray, pipe.batch_at(step))
            with watchdog:
                params, opt_state, metrics = jitted(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % cfg.log_every == 0 or step == cfg.steps - 1:
                log(f"step {step:5d} loss {loss:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f}")
            if ckpt and (step + 1) % cfg.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state})
        if ckpt:
            ckpt.save(cfg.steps, {"params": params, "opt": opt_state})
            ckpt.wait()
        wall = time.perf_counter() - t_start

    return {
        "params": params,
        "opt_state": opt_state,
        "losses": losses,
        "wall_s": wall,
        "stragglers": watchdog.straggler_steps,
    }
