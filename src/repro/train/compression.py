"""Gradient compression for the data-parallel reduce.

Two composable schemes on the explicit-collective (shard_map) DP path:

* ``bf16_allreduce`` -- cast f32 grads to bf16 for the wire, accumulate
  the cast error locally and add it back next step (error feedback keeps
  convergence unbiased).
* ``topk_sparsify`` -- keep the k largest-magnitude entries per tensor,
  exchange (values, indices); the residual goes into the error buffer.

Used by train/loop.py when the plan sets ``Layout step gradients BF16;``;
tests/test_substrates.py checks the error-feedback invariant (compressed
+ residual == original).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def bf16_compress(grads, error):
    """Returns (wire_grads bf16, new_error f32)."""
    def one(g, e):
        g = g.astype(jnp.float32) + (e if e is not None else 0.0)
        wire = g.astype(jnp.bfloat16)
        return wire, g - wire.astype(jnp.float32)
    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(error) if error is not None \
        else [None] * len(flat_g)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), \
        td.unflatten([o[1] for o in out])


def topk_sparsify(g: jax.Array, k_fraction: float = 0.01):
    """Returns (values, flat_indices, residual)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * k_fraction))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    residual = flat.at[idx].set(0.0).reshape(g.shape)
    return kept, idx, residual


def topk_restore(shape, vals, idx, dtype=jnp.float32):
    out = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), dtype)
    return out.at[idx].set(vals.astype(dtype)).reshape(shape)


def dp_allreduce_bf16(grads, axis_name: str):
    """Inside shard_map: bf16-wire psum of f32 grads (no error feedback
    needed across devices -- the cast happens once, symmetric)."""
    return jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis_name)
        .astype(jnp.float32), grads)
