"""AdamW optimizer (self-contained; fp32 moments over bf16 params).

The moment trees inherit the parameter shardings (FSDP'd params => ZeRO'd
optimizer state automatically); the DSL's ``Region step optimizer_state``
placement can override to replication for small models.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def _schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = _schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
