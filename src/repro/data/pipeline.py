"""Deterministic synthetic LM data pipeline, host-sharded.

Every batch is a pure function of (seed, step, host_index) -- so a
replacement host after a failure regenerates exactly its shard (the
elastic/straggler recovery story), and multi-host runs need no data
coordination.  Structured token streams (Zipf unigrams + a first-order
Markov mix) give a learnable signal for the convergence tests and the
quickstart example.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    batch: int              # per-host batch
    seq_len: int
    seed: int = 0
    n_hosts: int = 1
    host_index: int = 0
    markov_order: float = 0.85   # prob of following the Markov chain


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.RandomState(cfg.seed)
        v = cfg.vocab_size
        # fixed random Markov successor table + Zipf unigram dist
        self.successors = base.randint(0, v, size=(v, 4))
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = 1.0 / ranks ** 1.2
        self.unigram = probs / probs.sum()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + step * 997 + cfg.host_index) % (2**31))
        b, s, v = cfg.batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.choice(v, size=b, p=self.unigram)
        follow = rng.random((b, s)) < cfg.markov_order
        branch = rng.randint(0, 4, size=(b, s))
        fresh = rng.choice(v, size=(b, s), p=self.unigram)
        for t in range(1, s):
            nxt = self.successors[toks[:, t - 1], branch[:, t]]
            toks[:, t] = np.where(follow[:, t], nxt, fresh[:, t])
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_pipeline(vocab_size: int, batch: int, seq_len: int, seed: int = 0,
                  n_hosts: int = 1, host_index: int = 0) -> SyntheticLM:
    return SyntheticLM(DataConfig(vocab_size, batch, seq_len, seed,
                                  n_hosts, host_index))
