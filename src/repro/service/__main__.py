"""CLI for the mapper artifact registry + tuning service.

    python -m repro.service submit circuit pennant --iters 5 --wait
    python -m repro.service status
    python -m repro.service best --workload circuit
    python -m repro.service export <artifact-id> --out artifact.json
    python -m repro.service gc --keep 2

The store path defaults to ``$REPRO_MAPPER_STORE`` or
``mapper_store.db`` in the working directory; every subcommand takes
``--store`` to override.  ``submit`` without ``--wait`` still drains
before exiting (a CLI process cannot leave detached threads behind); use
the :class:`~repro.service.TuningService` API for long-lived services.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from . import MapperStore, TuningService

DEFAULT_STORE = os.environ.get("REPRO_MAPPER_STORE", "mapper_store.db")


def _fmt_score(score) -> str:
    return "-" if score is None else f"{score:.6g}"


def cmd_submit(args) -> int:
    from ..asi import registry
    known = registry.names()
    unknown = [w for w in args.workloads if w not in known]
    if unknown:
        print(f"error: unknown workload(s) {unknown}; see "
              "python -m repro.tune --list", file=sys.stderr)
        return 2
    service = TuningService(MapperStore(args.store), workers=args.workers,
                            checkpoint_dir=args.checkpoint_dir)
    timed_out = 0
    with service:
        jobs = [service.submit(w, strategy=args.strategy,
                               iterations=args.iters, batch=args.batch,
                               seed=args.seed,
                               feedback_level=args.feedback_level)
                for w in args.workloads]
        for job in jobs:
            print(f"{job.id}  {job.workload}@{job.key[1]}  {job.state}")
        try:
            service.drain(timeout=args.timeout or None)
        except TimeoutError:
            # tuning threads cannot be killed mid-compile, so the flag
            # bounds the *reported* outcome (exit 1), not the wait:
            # closing the pool below still joins the running jobs
            timed_out = sum(1 for j in jobs if not j.done())
            print(f"timeout: {timed_out} job(s) still running after "
                  f"{args.timeout:g}s; waiting for them to finish",
                  file=sys.stderr)
            service.drain()
    failed = 0
    for job in jobs:
        line = (f"{job.id}  {job.workload}  {job.state}  "
                f"best={_fmt_score(job.best_score)}  "
                f"artifact={job.artifact_id or '-'}")
        if job.resumed:
            line += "  (resumed)"
        print(line)
        if job.state != "done":
            failed += 1
            if job.error:
                print(job.error, file=sys.stderr)
    return 1 if failed or timed_out else 0


def cmd_status(args) -> int:
    store = MapperStore(args.store)
    rows = store.summary()
    if not rows:
        print(f"{args.store}: empty store")
        return 0
    w = max(len("workload"), *(len(r["workload"]) for r in rows)) + 2
    m = max(len("mesh"), *(len(r["mesh"]) for r in rows)) + 2
    print("workload".ljust(w) + "mesh".ljust(m)
          + "artifacts".rjust(10) + "best".rjust(14) + "  best_id")
    for r in rows:
        print(r["workload"].ljust(w) + r["mesh"].ljust(m)
              + str(r["artifacts"]).rjust(10)
              + _fmt_score(r["best_score"]).rjust(14)
              + f"  {(r['best_id'] or '-')[:12]}")
    print(f"{len(store)} artifact(s) across {len(rows)} key(s)")
    return 0


def cmd_best(args) -> int:
    store = MapperStore(args.store)
    art = store.best(args.workload, args.mesh)
    if art is None:
        print(f"no scored artifact for {args.workload!r}"
              + (f" @ {args.mesh}" if args.mesh else ""), file=sys.stderr)
        return 1
    print(f"id:          {art.id}")
    print(f"workload:    {art.workload}  ({art.substrate})")
    print(f"mesh:        {art.mesh}")
    print(f"score:       {_fmt_score(art.score)}")
    print(f"fingerprint: {art.fingerprint}")
    print(f"provenance:  {json.dumps(art.provenance, sort_keys=True)}")
    if args.show_mapper:
        print("mapper:")
        print(art.mapper)
    return 0


def cmd_export(args) -> int:
    store = MapperStore(args.store)
    art = store.get(args.id)
    if art is None:
        print(f"no artifact {args.id!r} in {args.store}", file=sys.stderr)
        return 1
    blob = json.dumps(art.to_dict(), indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob + "\n")
        print(f"wrote {args.out}")
    else:
        print(blob)
    return 0


def cmd_gc(args) -> int:
    store = MapperStore(args.store)
    deleted = store.gc(keep=args.keep)
    print(f"deleted {deleted} artifact(s); {len(store)} remain "
          f"(keep={args.keep} per workload x mesh)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="Mapper artifact registry + async tuning service.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def add_store(p):
        p.add_argument("--store", default=DEFAULT_STORE,
                       help=f"store path (default: {DEFAULT_STORE})")

    p = sub.add_parser("submit", help="enqueue tuning jobs and publish "
                                      "the winners to the store")
    p.add_argument("workloads", nargs="+", help="registry workload names")
    p.add_argument("--strategy", default="trace")
    p.add_argument("--iters", type=int, default=10)
    p.add_argument("--batch", type=int, default=1)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--feedback-level", default="full")
    p.add_argument("--workers", type=int, default=2)
    p.add_argument("--checkpoint-dir", default=None,
                   help="write/resume Tuner checkpoints here")
    p.add_argument("--timeout", type=float, default=0,
                   help="seconds before the submit is reported failed "
                        "(exit 1); running jobs are still joined -- "
                        "tuning threads cannot be killed mid-compile "
                        "(0 = no limit)")
    p.add_argument("--wait", action="store_true",
                   help="accepted for clarity; the CLI always drains")
    add_store(p)
    p.set_defaults(fn=cmd_submit)

    p = sub.add_parser("status", help="artifact inventory of the store")
    add_store(p)
    p.set_defaults(fn=cmd_status)

    p = sub.add_parser("best", help="show the best artifact for a workload")
    p.add_argument("--workload", required=True)
    p.add_argument("--mesh", default=None, help="geometry key, e.g. "
                                                "16x16:data,model")
    p.add_argument("--show-mapper", action="store_true")
    add_store(p)
    p.set_defaults(fn=cmd_best)

    p = sub.add_parser("export", help="dump one artifact as JSON")
    p.add_argument("id")
    p.add_argument("--out", default=None)
    add_store(p)
    p.set_defaults(fn=cmd_export)

    p = sub.add_parser("gc", help="prune all but the best artifacts per "
                                  "(workload, mesh)")
    p.add_argument("--keep", type=int, default=1)
    add_store(p)
    p.set_defaults(fn=cmd_gc)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
