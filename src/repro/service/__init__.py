"""repro.service -- the mapper artifact registry + async tuning service.

The layer that closes the loop from tuning to serving: tuned mappers
become first-class, portable artifacts instead of dying inside Tuner
checkpoints.

* :class:`MapperStore` -- content-addressed, versioned artifact store
  (sqlite index + JSON blobs) keyed by ``(workload, mesh geometry)``;
  each :class:`MapperArtifact` records DSL source, plan fingerprint,
  score, and provenance.  ``best()`` is the serving-side lookup.
* :class:`TuningService` -- a thread-pool job queue
  (``submit``/``status``/``cancel``/``drain``) running ``asi.Tuner``
  jobs concurrently, deduping in-flight jobs by store key, resuming from
  Tuner checkpoints, and publishing winners via :func:`publish_result`
  (the same path the ``Tuner(store=...)`` hook and the
  ``repro.experiments`` sweep use).
* :func:`resolve_mapper` -- artifact -> expert preset -> default
  resolution (plus optional tune-on-miss), so serving always has a
  mapper; ``repro.serve.Engine.from_store`` is the consumer.

CLI: ``python -m repro.service {submit,status,best,export,gc}``.
See docs/serving.md.
"""

from .jobs import (JOB_STATES, DrainTimeout, Job, JobSpec,
                   TuningService)
from .resolve import Resolution, preset_mapper, resolve_mapper
from .store import (MapperArtifact, MapperStore, mapper_fingerprint,
                    mesh_key, publish_result, workload_mesh,
                    workload_profile)

__all__ = [
    "DrainTimeout", "JOB_STATES", "Job", "JobSpec", "MapperArtifact",
    "MapperStore",
    "Resolution", "TuningService", "mapper_fingerprint", "mesh_key",
    "preset_mapper", "publish_result", "resolve_mapper", "workload_mesh",
    "workload_profile",
]
