"""The asynchronous tuning service: a job queue over ``asi.Tuner``.

VibeCodeHPC's lesson (PAPERS.md): an agent auto-tuner earns its keep
only when it runs *continuously* -- a persistent job/artifact layer, not
a one-shot script.  :class:`TuningService` is that layer: ``submit``
enqueues a tuning run on a worker pool, ``status``/``cancel``/``drain``
manage it, and every completed run publishes its winner to the
:class:`~repro.service.store.MapperStore` through the same
``publish_result`` path the Tuner hook and the experiments sweep use.

Two pool backends front the same submit/status/cancel/drain API:

* ``backend="thread"`` (default) -- jobs run on an in-process thread
  pool; workloads may be registry names or ad-hoc instances.
* ``backend="process"`` -- jobs run in spawned worker processes sharing
  the sqlite store file (WAL + write retry make concurrent publishes
  lossless); workloads must be registry *names* so the child can
  reconstruct them.  This is the pool the fleet racer scales out on.

Concurrency notes:

* Jobs **dedupe by store key**: a second ``submit`` for a workload whose
  ``(workload, mesh)`` key already has a queued/running job returns that
  in-flight job instead of double-tuning the same cell (the spec of the
  first submit wins).
* With a ``checkpoint_dir``, each job writes a Tuner JSON checkpoint
  named by its (key x spec); a later submit with the same spec *resumes*
  from it -- including the evalengine's ``.evalcache`` sidecar, so
  already-paid compiles are never repaid across service restarts.
* ``cancel`` of a *queued* job cancels it immediately; ``cancel`` of a
  *running* job sets a cooperative stop flag the Tuner polls at every
  iteration boundary -- the job halts, skips publication (a cancelled
  run never overwrites the leaderboard), and transitions to
  ``cancelled`` when the worker notices.
* ``drain(timeout=...)`` raises :class:`DrainTimeout` naming the jobs
  still pending; those jobs keep their consistent ``running``/``queued``
  state and remain visible to ``status``/``cancel``.
"""

from __future__ import annotations

import itertools
import math
import os
import re
import shutil
import tempfile
import threading
import time
import traceback
from concurrent.futures import ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

from .store import MapperStore, publish_result, workload_mesh

#: Job lifecycle: queued -> running -> done | failed | cancelled;
#: queued -> cancelled.
JOB_STATES = ("queued", "running", "done", "failed", "cancelled")

#: Worker-pool backends a TuningService can run jobs on.
BACKENDS = ("thread", "process")


def _slug(s: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]+", "_", s)


class DrainTimeout(TimeoutError):
    """``drain(timeout=...)`` elapsed with jobs still in flight.

    ``pending`` names the job ids that had not finished; they keep
    running with consistent state -- ``status()`` still tracks them and
    ``cancel()`` stops them -- instead of being silently orphaned.
    """

    def __init__(self, pending: List[str], timeout: Optional[float]):
        self.pending = list(pending)
        super().__init__(
            f"{len(self.pending)} job(s) still running after {timeout}s: "
            f"{', '.join(self.pending)}; they continue in the pool -- "
            "status() tracks them, cancel() stops them")


@dataclass
class JobSpec:
    """The tuning parameters of one job (mirrors the Tuner front door)."""

    strategy: str = "trace"
    iterations: int = 10
    batch: int = 1
    seed: int = 0
    feedback_level: str = "full"

    def slug(self) -> str:
        """Checkpoint-name component.  Deliberately excludes
        ``iterations``: re-submitting the same spec with more iterations
        must find -- and resume -- the earlier checkpoint."""
        return (f"{self.strategy}-b{self.batch}"
                f"-s{self.seed}-{self.feedback_level}")

    def to_dict(self) -> Dict:
        return {"strategy": self.strategy, "iterations": self.iterations,
                "batch": self.batch, "seed": self.seed,
                "feedback_level": self.feedback_level}


@dataclass
class Job:
    """One tracked tuning run."""

    id: str
    workload: str
    key: Tuple[str, str]       # (workload, mesh geometry) = the store key
    spec: JobSpec
    state: str = "queued"
    submitted: float = field(default_factory=time.time)
    started: Optional[float] = None
    finished: Optional[float] = None
    best_score: Optional[float] = None
    artifact_id: Optional[str] = None
    checkpoint: Optional[str] = None
    resumed: bool = False
    cancel_requested: bool = False
    error: Optional[str] = None
    future: Optional[object] = field(default=None, repr=False)
    #: Cooperative stop flag (thread backend polls the event; the
    #: process backend additionally signals via ``stop_path``).
    _stop: threading.Event = field(default_factory=threading.Event,
                                   repr=False)
    stop_path: Optional[str] = field(default=None, repr=False)

    def done(self) -> bool:
        return self.state in ("done", "failed", "cancelled")

    def summary(self) -> Dict:
        return {"id": self.id, "workload": self.workload,
                "mesh": self.key[1], "spec": self.spec.to_dict(),
                "state": self.state, "submitted": self.submitted,
                "started": self.started, "finished": self.finished,
                "best_score": self.best_score,
                "artifact_id": self.artifact_id,
                "checkpoint": self.checkpoint, "resumed": self.resumed,
                "cancel_requested": self.cancel_requested,
                "error": self.error}


def _process_job(store_path: str, workload: str, spec: Dict,
                 checkpoint: Optional[str], stop_path: Optional[str],
                 job_id: str) -> Dict:
    """Worker-process entry: run one Tuner job and publish its winner.

    Top-level (picklable) on purpose.  The child opens its *own* store
    connection on the shared sqlite file -- WAL + write retry make the
    concurrent publish lossless -- and honours the cooperative stop file
    at iteration boundaries, halting without publishing.
    """
    from ..asi import Tuner, registry
    wl = registry.get(workload)
    stop_fn = ((lambda: os.path.exists(stop_path)) if stop_path else None)
    resumed = False
    if checkpoint and os.path.exists(checkpoint):
        tuner = Tuner.from_checkpoint(checkpoint,
                                      iterations=spec["iterations"],
                                      workload=wl)
        tuner.stop = stop_fn
        resumed = True
        result = tuner.resume()
    else:
        tuner = Tuner(workload=wl, strategy=spec["strategy"],
                      iterations=spec["iterations"], batch=spec["batch"],
                      seed=spec["seed"],
                      feedback_level=spec["feedback_level"],
                      checkpoint=checkpoint, stop=stop_fn)
        result = tuner.run()
    out: Dict = {"resumed": resumed, "stopped": bool(result.stopped),
                 "best_score": None, "artifact_id": None}
    if result.stopped:
        return out
    store = MapperStore(store_path)
    try:
        artifact = publish_result(
            store, wl, result,
            provenance={"source": "service", "job": job_id,
                        "backend": "process", "checkpoint": checkpoint,
                        "resumed": resumed, **spec})
    finally:
        store.close()
    if math.isfinite(result.best_score):
        out["best_score"] = float(result.best_score)
    out["artifact_id"] = artifact.id if artifact else None
    return out


class TuningService:
    """Pooled tuning jobs that publish winners to a MapperStore.

    ``backend`` selects the worker pool: ``"thread"`` (in-process) or
    ``"process"`` (spawned workers sharing the store file; submit by
    registry name).
    """

    def __init__(self, store: Union[MapperStore, str], *, workers: int = 2,
                 checkpoint_dir: Optional[str] = None,
                 backend: str = "thread"):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"choose from {BACKENDS}")
        self.store = (store if isinstance(store, MapperStore)
                      else MapperStore(store))
        self.backend = backend
        self.checkpoint_dir = checkpoint_dir
        if checkpoint_dir:
            os.makedirs(checkpoint_dir, exist_ok=True)
        if backend == "process":
            import multiprocessing
            from concurrent.futures import ProcessPoolExecutor
            # spawn, not fork: worker processes re-import cleanly (JAX
            # and thread pools do not survive forks), matching how a
            # multi-host deployment would start them
            self._pool = ProcessPoolExecutor(
                max_workers=max(1, workers),
                mp_context=multiprocessing.get_context("spawn"))
            # stop files live here (cooperative cancel across processes)
            self._run_dir = tempfile.mkdtemp(prefix="tuning-service-")
        else:
            self._pool = ThreadPoolExecutor(max_workers=max(1, workers),
                                            thread_name_prefix="tuning")
            self._run_dir = None
        self._lock = threading.Lock()
        self._jobs: Dict[str, Job] = {}
        self._inflight: Dict[Tuple[str, str], Job] = {}
        self._ids = itertools.count(1)

    # -- submission ----------------------------------------------------------
    def submit(self, workload, *, strategy: str = "trace",
               iterations: int = 10, batch: int = 1, seed: int = 0,
               feedback_level: str = "full") -> Job:
        """Enqueue a tuning run; returns its :class:`Job` immediately.

        ``workload`` is a registry name or a ``Workload`` instance (the
        process backend requires registry names: the worker process must
        be able to reconstruct the workload).  If a job for the same
        ``(workload, mesh)`` store key is already queued or running,
        that job is returned instead (in-flight dedup).
        """
        if self.backend == "process" and not isinstance(workload, str):
            raise ValueError(
                "backend='process' requires a registry workload name "
                f"(got a {type(workload).__name__} instance): the worker "
                "process reconstructs the workload from the registry")
        from ..asi import registry
        wl = registry.get(workload) if isinstance(workload, str) else workload
        spec = JobSpec(strategy=strategy, iterations=iterations, batch=batch,
                       seed=seed, feedback_level=feedback_level)
        key = (wl.name, workload_mesh(wl))
        with self._lock:
            dup = self._inflight.get(key)
            if dup is not None:
                return dup
            job = Job(id=f"job-{next(self._ids):04d}", workload=wl.name,
                      key=key, spec=spec)
            if self.checkpoint_dir:
                job.checkpoint = os.path.join(
                    self.checkpoint_dir,
                    f"{_slug(wl.name)}@{_slug(key[1])}-{spec.slug()}.json")
            self._jobs[job.id] = job
            self._inflight[key] = job
            # inside the lock: a concurrent drain()/cancel() must never
            # observe the job without its future (the worker's _run
            # re-acquires the lock, so this cannot deadlock)
            if self.backend == "process":
                job.stop_path = os.path.join(self._run_dir,
                                             f"{job.id}.stop")
                job.started = time.time()    # pool start is opaque
                job.state = "running"
                job.future = self._pool.submit(
                    _process_job, self.store.path, wl.name, spec.to_dict(),
                    job.checkpoint, job.stop_path, job.id)
                job.future.add_done_callback(
                    lambda fut, j=job: self._finish_process(j, fut))
            else:
                job.future = self._pool.submit(self._run, job, wl)
        return job

    def _run(self, job: Job, wl) -> Job:
        with self._lock:
            if job.state == "cancelled" or job._stop.is_set():
                job.state = "cancelled"
                job.finished = job.finished or time.time()
                if self._inflight.get(job.key) is job:
                    del self._inflight[job.key]
                return job
            job.state = "running"
            job.started = time.time()
        try:
            from ..asi import Tuner
            if job.checkpoint and os.path.exists(job.checkpoint):
                tuner = Tuner.from_checkpoint(
                    job.checkpoint, iterations=job.spec.iterations,
                    workload=wl)
                tuner.stop = job._stop
                job.resumed = True
                result = tuner.resume()
            else:
                tuner = Tuner(workload=wl, strategy=job.spec.strategy,
                              iterations=job.spec.iterations,
                              batch=job.spec.batch, seed=job.spec.seed,
                              feedback_level=job.spec.feedback_level,
                              checkpoint=job.checkpoint, stop=job._stop)
                result = tuner.run()
            if result.stopped:
                # cancelled mid-run: halted at an iteration boundary,
                # nothing published -- the leaderboard is untouched
                job.state = "cancelled"
            else:
                artifact = publish_result(
                    self.store, wl, result,
                    provenance={"source": "service", "job": job.id,
                                "checkpoint": job.checkpoint,
                                "resumed": job.resumed,
                                **job.spec.to_dict()})
                if math.isfinite(result.best_score):
                    job.best_score = float(result.best_score)
                job.artifact_id = artifact.id if artifact else None
                job.state = "done"
        except Exception:
            job.error = traceback.format_exc(limit=8)
            job.state = "failed"
        finally:
            job.finished = time.time()
            with self._lock:
                if self._inflight.get(job.key) is job:
                    del self._inflight[job.key]
        return job

    def _finish_process(self, job: Job, fut) -> None:
        """Fold a finished process-backend future into its Job.

        Idempotent (drain calls it directly so results are visible the
        moment ``wait`` returns, without racing the done-callback)."""
        with self._lock:
            if job.done():
                return
            if fut.cancelled():
                job.state = "cancelled"
            else:
                err = fut.exception()
                if err is not None:
                    job.error = "".join(traceback.format_exception_only(
                        type(err), err)).strip()
                    job.state = "failed"
                else:
                    out = fut.result()
                    job.resumed = bool(out.get("resumed"))
                    if out.get("stopped"):
                        job.state = "cancelled"
                    else:
                        job.best_score = out.get("best_score")
                        job.artifact_id = out.get("artifact_id")
                        job.state = "done"
            job.finished = time.time()
            if self._inflight.get(job.key) is job:
                del self._inflight[job.key]

    # -- tracking ------------------------------------------------------------
    def status(self, job_id: Optional[str] = None):
        """Summary dict for one job, or all jobs (submission order)."""
        with self._lock:
            if job_id is not None:
                if job_id not in self._jobs:
                    raise KeyError(f"unknown job {job_id!r}; known: "
                                   f"{sorted(self._jobs)}")
                return self._jobs[job_id].summary()
            return [j.summary() for j in self._jobs.values()]

    def jobs(self) -> List[Job]:
        with self._lock:
            return list(self._jobs.values())

    def cancel(self, job_id: str) -> bool:
        """Cancel a job.  Queued jobs cancel immediately; running jobs
        get a cooperative stop flag the Tuner polls at every iteration
        boundary -- they halt, skip publication, and transition to
        ``cancelled`` when the worker notices (a job that completes
        before the next boundary still lands ``done``).  Returns True
        when cancellation was initiated, False for already-finished
        jobs."""
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None:
                raise KeyError(f"unknown job {job_id!r}")
            if job.done():
                return False
            if (job.state == "queued" and job.future is not None
                    and job.future.cancel()):
                job.state = "cancelled"
                job.finished = time.time()
                if self._inflight.get(job.key) is job:
                    del self._inflight[job.key]
                return True
            # running (or started before cancel landed): cooperative stop
            job.cancel_requested = True
            job._stop.set()
            if job.stop_path:
                with open(job.stop_path, "w") as f:
                    f.write("cancel\n")
            return True

    def drain(self, timeout: Optional[float] = None) -> List[Job]:
        """Wait for every submitted job to finish; returns all jobs.

        Raises :class:`DrainTimeout` -- naming the still-pending job ids
        -- if ``timeout`` (seconds) elapses first; the pending jobs keep
        running with consistent state (``status()`` tracks them,
        ``cancel()`` stops them)."""
        by_future = {j.future: j for j in self.jobs()
                     if j.future is not None}
        done, pending = wait(list(by_future), timeout=timeout)
        if self.backend == "process":
            for fut in done:            # don't race the done-callback
                self._finish_process(by_future[fut], fut)
        if pending:
            raise DrainTimeout(sorted(by_future[f].id for f in pending),
                               timeout)
        return self.jobs()

    def close(self) -> None:
        self._pool.shutdown(wait=True)
        if self._run_dir:
            shutil.rmtree(self._run_dir, ignore_errors=True)

    def __enter__(self) -> "TuningService":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def __repr__(self) -> str:
        with self._lock:
            states: Dict[str, int] = {}
            for j in self._jobs.values():
                states[j.state] = states.get(j.state, 0) + 1
        return (f"<TuningService backend={self.backend} jobs={states} "
                f"store={self.store.path!r}>")
