"""The asynchronous tuning service: a job queue over ``asi.Tuner``.

VibeCodeHPC's lesson (PAPERS.md): an agent auto-tuner earns its keep
only when it runs *continuously* -- a persistent job/artifact layer, not
a one-shot script.  :class:`TuningService` is that layer: ``submit``
enqueues a tuning run on a thread pool, ``status``/``cancel``/``drain``
manage it, and every completed run publishes its winner to the
:class:`~repro.service.store.MapperStore` through the same
``publish_result`` path the Tuner hook and the experiments sweep use.

Concurrency notes:

* Jobs **dedupe by store key**: a second ``submit`` for a workload whose
  ``(workload, mesh)`` key already has a queued/running job returns that
  in-flight job instead of double-tuning the same cell (the spec of the
  first submit wins).
* With a ``checkpoint_dir``, each job writes a Tuner JSON checkpoint
  named by its (key x spec); a later submit with the same spec *resumes*
  from it -- including the evalengine's ``.evalcache`` sidecar, so
  already-paid compiles are never repaid across service restarts.
* Workloads whose evaluators are not thread-safe stay safe: the Tuner's
  own loop enforces ``parallel_safe`` per workload, and distinct jobs
  touch distinct workload instances via the registry.
"""

from __future__ import annotations

import itertools
import math
import os
import re
import threading
import time
import traceback
from concurrent.futures import ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

from .store import MapperStore, publish_result, workload_mesh

#: Job lifecycle: queued -> running -> done | failed; queued -> cancelled.
JOB_STATES = ("queued", "running", "done", "failed", "cancelled")


def _slug(s: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]+", "_", s)


@dataclass
class JobSpec:
    """The tuning parameters of one job (mirrors the Tuner front door)."""

    strategy: str = "trace"
    iterations: int = 10
    batch: int = 1
    seed: int = 0
    feedback_level: str = "full"

    def slug(self) -> str:
        """Checkpoint-name component.  Deliberately excludes
        ``iterations``: re-submitting the same spec with more iterations
        must find -- and resume -- the earlier checkpoint."""
        return (f"{self.strategy}-b{self.batch}"
                f"-s{self.seed}-{self.feedback_level}")

    def to_dict(self) -> Dict:
        return {"strategy": self.strategy, "iterations": self.iterations,
                "batch": self.batch, "seed": self.seed,
                "feedback_level": self.feedback_level}


@dataclass
class Job:
    """One tracked tuning run."""

    id: str
    workload: str
    key: Tuple[str, str]       # (workload, mesh geometry) = the store key
    spec: JobSpec
    state: str = "queued"
    submitted: float = field(default_factory=time.time)
    started: Optional[float] = None
    finished: Optional[float] = None
    best_score: Optional[float] = None
    artifact_id: Optional[str] = None
    checkpoint: Optional[str] = None
    resumed: bool = False
    error: Optional[str] = None
    future: Optional[object] = field(default=None, repr=False)

    def done(self) -> bool:
        return self.state in ("done", "failed", "cancelled")

    def summary(self) -> Dict:
        return {"id": self.id, "workload": self.workload,
                "mesh": self.key[1], "spec": self.spec.to_dict(),
                "state": self.state, "submitted": self.submitted,
                "started": self.started, "finished": self.finished,
                "best_score": self.best_score,
                "artifact_id": self.artifact_id,
                "checkpoint": self.checkpoint, "resumed": self.resumed,
                "error": self.error}


class TuningService:
    """Thread-pool tuning jobs that publish winners to a MapperStore."""

    def __init__(self, store: Union[MapperStore, str], *, workers: int = 2,
                 checkpoint_dir: Optional[str] = None):
        self.store = (store if isinstance(store, MapperStore)
                      else MapperStore(store))
        self.checkpoint_dir = checkpoint_dir
        if checkpoint_dir:
            os.makedirs(checkpoint_dir, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=max(1, workers),
                                        thread_name_prefix="tuning")
        self._lock = threading.Lock()
        self._jobs: Dict[str, Job] = {}
        self._inflight: Dict[Tuple[str, str], Job] = {}
        self._ids = itertools.count(1)

    # -- submission ----------------------------------------------------------
    def submit(self, workload, *, strategy: str = "trace",
               iterations: int = 10, batch: int = 1, seed: int = 0,
               feedback_level: str = "full") -> Job:
        """Enqueue a tuning run; returns its :class:`Job` immediately.

        ``workload`` is a registry name or a ``Workload`` instance.  If a
        job for the same ``(workload, mesh)`` store key is already queued
        or running, that job is returned instead (in-flight dedup).
        """
        from ..asi import registry
        wl = registry.get(workload) if isinstance(workload, str) else workload
        spec = JobSpec(strategy=strategy, iterations=iterations, batch=batch,
                       seed=seed, feedback_level=feedback_level)
        key = (wl.name, workload_mesh(wl))
        with self._lock:
            dup = self._inflight.get(key)
            if dup is not None:
                return dup
            job = Job(id=f"job-{next(self._ids):04d}", workload=wl.name,
                      key=key, spec=spec)
            if self.checkpoint_dir:
                job.checkpoint = os.path.join(
                    self.checkpoint_dir,
                    f"{_slug(wl.name)}@{_slug(key[1])}-{spec.slug()}.json")
            self._jobs[job.id] = job
            self._inflight[key] = job
            # inside the lock: a concurrent drain()/cancel() must never
            # observe the job without its future (the worker's _run
            # re-acquires the lock, so this cannot deadlock)
            job.future = self._pool.submit(self._run, job, wl)
        return job

    def _run(self, job: Job, wl) -> Job:
        with self._lock:
            if job.state == "cancelled":
                return job
            job.state = "running"
            job.started = time.time()
        try:
            from ..asi import Tuner
            if job.checkpoint and os.path.exists(job.checkpoint):
                tuner = Tuner.from_checkpoint(
                    job.checkpoint, iterations=job.spec.iterations,
                    workload=wl)
                job.resumed = True
                result = tuner.resume()
            else:
                tuner = Tuner(workload=wl, strategy=job.spec.strategy,
                              iterations=job.spec.iterations,
                              batch=job.spec.batch, seed=job.spec.seed,
                              feedback_level=job.spec.feedback_level,
                              checkpoint=job.checkpoint)
                result = tuner.run()
            artifact = publish_result(
                self.store, wl, result,
                provenance={"source": "service", "job": job.id,
                            "checkpoint": job.checkpoint,
                            "resumed": job.resumed, **job.spec.to_dict()})
            if math.isfinite(result.best_score):
                job.best_score = float(result.best_score)
            job.artifact_id = artifact.id if artifact else None
            job.state = "done"
        except Exception:
            job.error = traceback.format_exc(limit=8)
            job.state = "failed"
        finally:
            job.finished = time.time()
            with self._lock:
                if self._inflight.get(job.key) is job:
                    del self._inflight[job.key]
        return job

    # -- tracking ------------------------------------------------------------
    def status(self, job_id: Optional[str] = None):
        """Summary dict for one job, or all jobs (submission order)."""
        with self._lock:
            if job_id is not None:
                if job_id not in self._jobs:
                    raise KeyError(f"unknown job {job_id!r}; known: "
                                   f"{sorted(self._jobs)}")
                return self._jobs[job_id].summary()
            return [j.summary() for j in self._jobs.values()]

    def jobs(self) -> List[Job]:
        with self._lock:
            return list(self._jobs.values())

    def cancel(self, job_id: str) -> bool:
        """Cancel a *queued* job; running jobs are not interrupted
        (tuning iterations are checkpointed, not killable mid-compile).
        Returns True when the job was cancelled."""
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None:
                raise KeyError(f"unknown job {job_id!r}")
            if job.state != "queued":
                return False
            if job.future is not None and not job.future.cancel():
                return False    # the pool already started it
            job.state = "cancelled"
            job.finished = time.time()
            if self._inflight.get(job.key) is job:
                del self._inflight[job.key]
            return True

    def drain(self, timeout: Optional[float] = None) -> List[Job]:
        """Wait for every submitted job to finish; returns all jobs.
        Raises TimeoutError if ``timeout`` (seconds) elapses first."""
        futures = [j.future for j in self.jobs() if j.future is not None]
        done, pending = wait(futures, timeout=timeout)
        if pending:
            raise TimeoutError(f"{len(pending)} job(s) still running "
                               f"after {timeout}s")
        return self.jobs()

    def close(self) -> None:
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "TuningService":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def __repr__(self) -> str:
        with self._lock:
            states: Dict[str, int] = {}
            for j in self._jobs.values():
                states[j.state] = states.get(j.state, 0) + 1
        return f"<TuningService jobs={states} store={self.store.path!r}>"
