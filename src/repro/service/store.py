"""The mapper artifact registry: tuned mappers as first-class artifacts.

A tuned mapper used to die inside its Tuner checkpoint; nothing routed
the winners that tuning finds into anything that serves.  The
:class:`MapperStore` makes mapping decisions portable artifacts (the
Mapple observation: a mapping is a small, versionable object keyed by
machine geometry): each :class:`MapperArtifact` records the mapper DSL
source, its plan fingerprint (reusing the evaluation engine's
canonicalization when the workload exposes it), the achieved score, and
full provenance (strategy, feedback level, seed, checkpoint reference).

Storage is a sqlite index over JSON blobs -- the same stdlib,
transactional, multi-process-safe choice as the evalengine
:class:`~repro.core.evalengine.store.DiskCache` -- content-addressed by
the sha256 of ``(workload, substrate, mesh, mapper, fingerprint)``, so
re-publishing an identical winner is idempotent.  ``best(workload,
mesh)`` is the serving-side resolution primitive; the expert-preset
fallback lives in :mod:`repro.service.resolve`.
"""

from __future__ import annotations

import hashlib
import json
import os
import random
import sqlite3
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

#: Bump when the artifact schema changes.  Enforced via sqlite's
#: ``user_version`` pragma: opening a store written at a *newer* version
#: raises instead of misreading rows one by one; older versions with a
#: known upgrade path are migrated in place (v1 -> v2 added the device-
#: profile axis; pre-profile artifacts are all ``profile="healthy"``).
STORE_VERSION = 2


# ---------------------------------------------------------------------------
# Keys
# ---------------------------------------------------------------------------
def _fmt_geometry(shape, axes=()) -> str:
    desc = "x".join(str(int(s)) for s in shape)
    if axes:
        desc += ":" + ",".join(axes)
    return desc


def mesh_key(mesh) -> str:
    """Geometry key of a (real or abstract) mesh: ``16x16:data,model``."""
    if isinstance(mesh, str):
        return mesh
    return _fmt_geometry(mesh.devices.shape, tuple(mesh.axis_names))


def workload_mesh(workload) -> str:
    """The machine-geometry key a workload tunes over.

    A workload may declare its own via a ``mesh_geometry()`` method;
    otherwise the key is derived from the substrate: LM cells tune on
    the production mesh (the multi-pod variant when ``multi_pod``, the
    host mesh when ``smoke``), the task-graph apps and the matmul
    algorithms on their fixed paper machines.
    """
    mg = getattr(workload, "mesh_geometry", None)
    if callable(mg):
        return str(mg())
    sub = getattr(workload, "substrate", "")
    if sub == "lm":
        if getattr(workload, "smoke", False):
            from ..launch.mesh import make_host_mesh
            return mesh_key(make_host_mesh())
        if getattr(workload, "multi_pod", False):
            return _fmt_geometry((2, 16, 16), ("pod", "data", "model"))
        return _fmt_geometry((16, 16), ("data", "model"))
    if sub in ("app", "app-jax"):
        from ..asi.adapters_apps import APP_MACHINE
        return _fmt_geometry(APP_MACHINE)
    if sub == "matmul":
        from ..asi.adapters_mm import MM_MACHINE
        return _fmt_geometry(MM_MACHINE)
    return "any"


def mapper_fingerprint(workload, mapper_src: str) -> str:
    """Plan fingerprint of ``mapper_src`` in the workload's cell.

    Reuses the evaluation engine's canonicalization when the workload's
    (already-constructed) evaluator exposes one -- two textually
    different mappers with the same canonical plan get the same
    fingerprint.  Falls back to an exact-text hash: constructing an LM
    cell context just to fingerprint would cost a mesh build.
    """
    from ..core.evalengine.fingerprint import text_key
    evaluator = getattr(workload, "_evaluator", None)
    own = getattr(evaluator, "mapper_fingerprint", None)
    if own is not None:     # evaluator with native canonicalization
        try:
            return own(mapper_src)
        except Exception:
            pass
    engine = getattr(evaluator, "engine", None)
    ctx = getattr(engine, "ctx", None)
    if ctx is not None:
        try:
            return ctx.fingerprint(ctx.compile_mapper(mapper_src))
        except Exception:
            pass
    return "text:" + text_key(mapper_src)


# ---------------------------------------------------------------------------
# Artifact
# ---------------------------------------------------------------------------
@dataclass
class MapperArtifact:
    """One published mapper: source + identity + score + provenance."""

    workload: str
    substrate: str
    mesh: str             # machine-geometry key (see mesh_key)
    mapper: str           # DSL source
    fingerprint: str      # plan fingerprint (or "text:<sha1>" fallback)
    #: Device-profile key ("healthy" | "straggler:<f>x<n>" | "shrink:<k>",
    #: see repro.ft.profiles) -- the machine state this mapper was tuned
    #: for.  The third axis of the store key.
    profile: str = "healthy"
    score: Optional[float] = None     # seconds, lower better; None = unscored
    provenance: Dict = field(default_factory=dict)
    created: float = 0.0
    id: str = ""          # content address; filled by build()/the store

    @classmethod
    def build(cls, workload: str, substrate: str, mesh: str, mapper: str, *,
              profile: str = "healthy", fingerprint: str = "",
              score: Optional[float] = None,
              provenance: Optional[Dict] = None,
              created: Optional[float] = None) -> "MapperArtifact":
        if not fingerprint:
            from ..core.evalengine.fingerprint import text_key
            fingerprint = "text:" + text_key(mapper)
        art = cls(workload=workload, substrate=substrate, mesh=mesh,
                  mapper=mapper, fingerprint=fingerprint, profile=profile,
                  score=score, provenance=dict(provenance or {}),
                  created=time.time() if created is None else created)
        art.id = art.content_id()
        return art

    def content_id(self) -> str:
        """Content address: identity fields only, not score/provenance --
        re-publishing the same mapper for the same cell is idempotent."""
        blob = json.dumps(
            {"v": STORE_VERSION, "workload": self.workload,
             "substrate": self.substrate, "mesh": self.mesh,
             "profile": self.profile,
             "mapper": self.mapper, "fingerprint": self.fingerprint},
            sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()

    def key(self) -> Tuple[str, str, str]:
        return (self.workload, self.mesh, self.profile)

    def to_dict(self) -> Dict:
        return {"id": self.id, "workload": self.workload,
                "substrate": self.substrate, "mesh": self.mesh,
                "profile": self.profile,
                "mapper": self.mapper, "fingerprint": self.fingerprint,
                "score": self.score, "provenance": self.provenance,
                "created": self.created}

    @classmethod
    def from_dict(cls, d: Dict) -> "MapperArtifact":
        return cls(workload=d["workload"], substrate=d["substrate"],
                   mesh=d["mesh"], mapper=d["mapper"],
                   fingerprint=d["fingerprint"],
                   profile=d.get("profile", "healthy"),
                   score=d.get("score"),
                   provenance=d.get("provenance", {}),
                   created=d.get("created", 0.0), id=d.get("id", ""))


# ---------------------------------------------------------------------------
# Store
# ---------------------------------------------------------------------------
def _is_locked_error(err: BaseException) -> bool:
    """A transient SQLITE_BUSY/SQLITE_LOCKED condition (another process
    holds the write lock), as opposed to a real operational failure."""
    msg = str(err).lower()
    return "locked" in msg or "busy" in msg


class MapperStore:
    """Content-addressed, versioned mapper registry over sqlite.

    Safe for concurrent use from threads *and* processes: connections
    open in WAL journal mode (readers never block the writer and vice
    versa) with a ``busy_timeout``, and every write retries with bounded
    exponential backoff on transient ``database is locked`` errors -- a
    fleet of worker processes hammering ``publish_result`` on one store
    file never loses a published winner.
    """

    #: Write attempts on SQLITE_BUSY before giving up (on top of the
    #: connection-level busy_timeout, which already waits inside sqlite).
    _WRITE_RETRIES = 6

    def __init__(self, path: str, *, timeout_s: float = 5.0):
        self.path = path
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self._lock = threading.RLock()
        self._conn = sqlite3.connect(path, check_same_thread=False,
                                     timeout=timeout_s)
        self._conn.execute(f"PRAGMA busy_timeout = {int(timeout_s * 1000)}")
        try:
            # WAL lets concurrent worker processes read the leaderboard
            # while another publishes; falls back silently where the
            # filesystem cannot support it (some network mounts).
            self.journal_mode = str(self._conn.execute(
                "PRAGMA journal_mode = WAL").fetchone()[0]).lower()
            self._conn.execute("PRAGMA synchronous = NORMAL")
        except sqlite3.OperationalError:
            self.journal_mode = "unknown"
        self._retry_write(lambda: self._init_schema(path))

    def _retry_write(self, fn):
        """Run ``fn`` under the thread lock, retrying on transient lock
        contention with bounded exponential backoff + jitter."""
        delay = 0.01
        for attempt in range(self._WRITE_RETRIES):
            try:
                with self._lock:
                    return fn()
            except sqlite3.OperationalError as e:
                if not _is_locked_error(e) \
                        or attempt == self._WRITE_RETRIES - 1:
                    raise
                try:
                    with self._lock:
                        self._conn.rollback()
                except sqlite3.OperationalError:
                    pass
                time.sleep(delay * (1.0 + random.random()))
                delay = min(delay * 2, 0.25)

    def _init_schema(self, path: str) -> None:
        ver = int(self._conn.execute(
            "PRAGMA user_version").fetchone()[0])
        has_table = self._conn.execute(
            "SELECT name FROM sqlite_master WHERE type='table' "
            "AND name='artifacts'").fetchone() is not None
        if has_table and ver not in (1, STORE_VERSION):
            self._conn.close()
            raise ValueError(
                f"mapper store {path!r} is schema version {ver}, "
                f"this code expects {STORE_VERSION}; migrate or "
                "start a fresh store")
        if has_table and ver == 1:
            # v1 -> v2: the device-profile axis.  Every pre-profile
            # artifact was tuned on the healthy machine, so the new
            # column backfills to "healthy"; ids and payloads are
            # untouched (payloads without a profile field resolve
            # as healthy on read).
            self._conn.execute(
                "ALTER TABLE artifacts ADD COLUMN profile TEXT "
                "NOT NULL DEFAULT 'healthy'")
        self._conn.execute(
            f"PRAGMA user_version = {int(STORE_VERSION)}")
        self._conn.execute(
            "CREATE TABLE IF NOT EXISTS artifacts ("
            "  id TEXT PRIMARY KEY,"
            "  workload TEXT NOT NULL,"
            "  substrate TEXT NOT NULL,"
            "  mesh TEXT NOT NULL,"
            "  profile TEXT NOT NULL DEFAULT 'healthy',"
            "  fingerprint TEXT NOT NULL,"
            "  score REAL,"
            "  created REAL NOT NULL,"
            "  payload TEXT NOT NULL)")
        self._conn.execute(
            "CREATE INDEX IF NOT EXISTS idx_artifacts_key "
            "ON artifacts (workload, mesh)")
        self._conn.execute(
            "CREATE INDEX IF NOT EXISTS idx_artifacts_profile "
            "ON artifacts (workload, mesh, profile)")
        self._conn.commit()

    # -- write --------------------------------------------------------------
    def put(self, artifact: MapperArtifact) -> MapperArtifact:
        """Insert (or idempotently refresh) an artifact; returns it with
        its content address filled in.  Retries on transient cross-
        process lock contention, so a concurrent fleet never loses a
        published winner."""
        if not artifact.id:
            artifact.id = artifact.content_id()
        blob = json.dumps(artifact.to_dict(), allow_nan=False)

        def write():
            self._conn.execute(
                "INSERT OR REPLACE INTO artifacts "
                "(id, workload, substrate, mesh, profile, fingerprint, "
                " score, created, payload) "
                "VALUES (?, ?, ?, ?, ?, ?, ?, ?, ?)",
                (artifact.id, artifact.workload, artifact.substrate,
                 artifact.mesh, artifact.profile, artifact.fingerprint,
                 artifact.score, artifact.created, blob))
            self._conn.commit()

        self._retry_write(write)
        return artifact

    # -- read ---------------------------------------------------------------
    def get(self, artifact_id: str) -> Optional[MapperArtifact]:
        with self._lock:
            row = self._conn.execute(
                "SELECT payload FROM artifacts WHERE id = ?",
                (artifact_id,)).fetchone()
        if row is None:
            return None
        try:
            return MapperArtifact.from_dict(json.loads(row[0]))
        except (json.JSONDecodeError, KeyError):
            return None    # corrupt blob: treat as a miss

    def best(self, workload: str, mesh: Optional[str] = None,
             profile: Optional[str] = "healthy"
             ) -> Optional[MapperArtifact]:
        """Lowest-scoring artifact for ``(workload, mesh, profile)``.

        ``mesh`` is a geometry key (or a mesh; see :func:`mesh_key`);
        ``None`` matches any geometry -- mappers do not port across
        geometries, so serving callers should always pin one.
        ``profile`` defaults to ``"healthy"`` (pre-profile behaviour);
        pass a profile key for degraded-machine artifacts, or ``None``
        to match any profile.  Unscored artifacts never win.
        """
        q = ("SELECT payload FROM artifacts WHERE workload = ? "
             "AND score IS NOT NULL")
        args: List = [workload]
        if mesh is not None:
            q += " AND mesh = ?"
            args.append(mesh_key(mesh))
        if profile is not None:
            q += " AND profile = ?"
            args.append(profile)
        q += " ORDER BY score ASC, created DESC LIMIT 1"
        with self._lock:
            row = self._conn.execute(q, args).fetchone()
        return (MapperArtifact.from_dict(json.loads(row[0]))
                if row else None)

    def list(self, workload: Optional[str] = None,
             mesh: Optional[str] = None,
             profile: Optional[str] = None) -> List[MapperArtifact]:
        q = "SELECT payload FROM artifacts"
        conds, args = [], []
        if workload is not None:
            conds.append("workload = ?")
            args.append(workload)
        if mesh is not None:
            conds.append("mesh = ?")
            args.append(mesh_key(mesh))
        if profile is not None:
            conds.append("profile = ?")
            args.append(profile)
        if conds:
            q += " WHERE " + " AND ".join(conds)
        q += (" ORDER BY workload, mesh, profile, (score IS NULL), "
              "score, created DESC")
        with self._lock:
            rows = self._conn.execute(q, args).fetchall()
        return [MapperArtifact.from_dict(json.loads(r[0])) for r in rows]

    def keys(self) -> List[Tuple[str, str, str]]:
        """Every distinct (workload, mesh, profile) key in the store --
        the iteration primitive for trace mining and the neighbor index
        (:mod:`repro.meta`) as well as per-key garbage collection."""
        with self._lock:
            rows = self._conn.execute(
                "SELECT DISTINCT workload, mesh, profile FROM artifacts "
                "ORDER BY workload, mesh, profile").fetchall()
        return [tuple(r) for r in rows]

    def summary(self) -> List[Dict]:
        """One row per (workload, mesh, profile): count + current best."""
        with self._lock:
            rows = self._conn.execute(
                "SELECT workload, mesh, profile, COUNT(*), MIN(score) "
                "FROM artifacts GROUP BY workload, mesh, profile "
                "ORDER BY workload, mesh, profile").fetchall()
        out = []
        for workload, mesh, profile, count, best_score in rows:
            best = self.best(workload, mesh, profile)
            out.append({"workload": workload, "mesh": mesh,
                        "profile": profile,
                        "artifacts": count, "best_score": best_score,
                        "best_id": best.id if best else None})
        return out

    # -- maintenance --------------------------------------------------------
    def gc(self, keep: int = 1) -> int:
        """Keep the ``keep`` best artifacts per (workload, mesh,
        profile); delete the rest (unscored artifacts are pruned
        first).  Returns the number deleted."""
        if keep < 0:
            raise ValueError("keep must be >= 0")

        def sweep():
            deleted = 0
            for workload, mesh, profile in self.keys():
                ids = [r[0] for r in self._conn.execute(
                    "SELECT id FROM artifacts WHERE workload = ? "
                    "AND mesh = ? AND profile = ? "
                    "ORDER BY (score IS NULL), score, created DESC",
                    (workload, mesh, profile)).fetchall()]
                for aid in ids[keep:]:
                    self._conn.execute(
                        "DELETE FROM artifacts WHERE id = ?", (aid,))
                    deleted += 1
            self._conn.commit()
            return deleted

        return self._retry_write(sweep)

    def __contains__(self, artifact_id: str) -> bool:
        return self.get(artifact_id) is not None

    def __len__(self) -> int:
        with self._lock:
            return int(self._conn.execute(
                "SELECT COUNT(*) FROM artifacts").fetchone()[0])

    def close(self) -> None:
        with self._lock:
            self._conn.close()

    def __enter__(self) -> "MapperStore":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def __repr__(self) -> str:
        return f"<MapperStore {self.path!r} artifacts={len(self)}>"


# ---------------------------------------------------------------------------
# Publishing (the one path tuner / service / experiments all go through)
# ---------------------------------------------------------------------------
def workload_profile(workload) -> str:
    """The device-profile key a workload's winner publishes under.

    Robust workloads (:class:`~repro.ft.robust.RobustWorkload`) expose
    ``profile_key()`` -- the most degraded profile of their tuning
    distribution; everything else tunes on the healthy machine.
    """
    pk = getattr(workload, "profile_key", None)
    return str(pk()) if callable(pk) else "healthy"


def publish_result(store: MapperStore, workload, result,
                   provenance: Optional[Dict] = None,
                   profile: Optional[str] = None
                   ) -> Optional[MapperArtifact]:
    """Publish a tuning run's winner (a ``SearchResult``) to ``store``.

    Returns ``None`` -- publishing nothing -- when the run found no valid
    candidate (no finite best score): the registry only holds mappers
    that actually executed.  ``profile`` overrides the store-axis key
    the artifact lands under (default: :func:`workload_profile`).
    """
    import math
    score = result.best_score
    if score is None or not math.isfinite(score) or not result.best_mapper:
        return None
    provenance = dict(provenance or {})
    # the winner's decision assignment rides along (JSON-normal form):
    # warm start (repro.meta) re-seeds new tuning runs from neighbor
    # artifacts' decisions without re-parsing mapper source
    decisions = getattr(result, "best_decisions", None)
    if decisions and "decisions" not in provenance:
        provenance["decisions"] = json.loads(json.dumps(decisions))
    return store.put(MapperArtifact.build(
        workload=workload.name,
        substrate=getattr(workload, "substrate", ""),
        mesh=workload_mesh(workload),
        mapper=result.best_mapper,
        profile=profile if profile is not None else
        workload_profile(workload),
        fingerprint=mapper_fingerprint(workload, result.best_mapper),
        score=float(score),
        provenance=provenance))
