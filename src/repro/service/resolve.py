"""Mapper resolution: artifact -> expert preset -> default, never empty.

Serving must always have a mapper.  ``resolve_mapper`` looks the
workload up in the :class:`~repro.service.store.MapperStore` by its
``(workload, mesh geometry)`` key; on a miss it falls back to the
expert-written preset (:mod:`repro.core.mapping.presets` for LM cells,
the workload's own ``expert_mapper`` otherwise) and finally to the
workload's default decisions.  With ``tune_on_miss`` and a
:class:`~repro.service.jobs.TuningService`, a miss additionally enqueues
a background tuning job so the *next* resolution finds an artifact --
serving is never blocked on tuning.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from .store import MapperStore, MapperArtifact, mesh_key


@dataclass
class Resolution:
    """Where a serving mapper came from."""

    mapper: str
    origin: str                 # "artifact" | "preset" | "default"
    workload: str
    mesh: Optional[str] = None
    artifact: Optional[MapperArtifact] = None
    job: Optional[object] = None    # tune-on-miss Job, when one was enqueued
    #: Device-profile key the caller asked for (the artifact's own
    #: ``profile`` says what was actually served -- a degraded request
    #: may fall back to the healthy artifact).
    profile: str = "healthy"

    def __repr__(self) -> str:
        ref = self.artifact.id[:12] if self.artifact else "-"
        served = self.artifact.profile if self.artifact else "-"
        return (f"<Resolution {self.workload!r}@{self.mesh} "
                f"origin={self.origin} artifact={ref} "
                f"profile={self.profile}->{served}>")


def _workload_instance(workload):
    if isinstance(workload, str):
        from ..asi import registry
        reg = registry.populate()
        return reg.get(workload) if workload in reg else None
    return workload


def preset_mapper(workload, step: str = "decode") -> Optional[str]:
    """The expert-written fallback for a workload (name or instance).

    LM cells -- registered or ad hoc ``lm/<arch>/...`` names -- use the
    per-arch expert presets; other workloads use their own
    ``expert_mapper`` when they ship one.
    """
    name = workload if isinstance(workload, str) else workload.name
    if name.startswith("lm/"):
        from ..core.mapping.presets import expert_mapper
        return expert_mapper(name.split("/")[1], step)
    wl = _workload_instance(workload)
    return getattr(wl, "expert_mapper", None) if wl is not None else None


def resolve_mapper(store: Optional[MapperStore], workload, mesh=None, *,
                   step: str = "decode", profile: str = "healthy",
                   service=None, tune_on_miss: bool = False) -> Resolution:
    """Resolve the mapper to serve ``workload`` on ``mesh``.

    ``workload`` is a registry name or a ``Workload`` instance; ``mesh``
    a real/abstract mesh, a geometry key string, or None (any geometry
    -- artifacts do not port across geometries, so serving callers
    should pin one).  ``profile`` is a device-profile key
    (:mod:`repro.ft.profiles`): the fallback chain is *profile artifact
    -> healthy artifact -> expert preset -> rendered defaults*, so a
    degraded mesh always serves the most specific mapper available and
    never blocks.  On a store miss with ``tune_on_miss`` and a
    ``service``, a background tuning job is enqueued (deduped by the
    service) and returned on the Resolution.
    """
    name = workload if isinstance(workload, str) else workload.name
    mkey = mesh_key(mesh) if mesh is not None else None
    art = store.best(name, mkey, profile) if store is not None else None
    if art is None and store is not None and profile != "healthy":
        art = store.best(name, mkey, "healthy")
    if art is not None:
        return Resolution(art.mapper, "artifact", name, mkey, artifact=art,
                          profile=profile)

    job = None
    if tune_on_miss and service is not None:
        from .store import workload_mesh
        wl = _workload_instance(workload)
        # only enqueue when the tuned artifact would land under the
        # requested key: the workload tunes on workload_mesh(wl), and
        # mappers do not port across geometries -- a mismatched enqueue
        # would re-tune on every resolve without ever serving
        if wl is not None and (mkey is None or workload_mesh(wl) == mkey):
            # pass the registry name through when the caller gave one so
            # process-backend services (name-only submit) can resolve too
            job = service.submit(workload if isinstance(workload, str)
                                 else wl)
    preset = preset_mapper(workload, step)
    if preset:
        return Resolution(preset, "preset", name, mkey, job=job,
                          profile=profile)
    wl = _workload_instance(workload)
    if wl is None:
        raise KeyError(
            f"cannot resolve a mapper for unknown workload {name!r}: no "
            "store artifact, no expert preset, and not in the registry")
    return Resolution(wl.render_mapper(wl.default_decisions()), "default",
                      name, mkey, job=job, profile=profile)
